//! Bit-accurate approximate-multiplier substrate.
//!
//! The paper characterizes approximate multipliers only by their (MRE,
//! SD) and cites hardware designs ([3]-[6]) for the speed/power/area
//! numbers. To close the loop we implement the cited designs (or their
//! closest published form) **bit-accurately** on unsigned integers:
//!
//! * [`Drum`] — DRUM (Hashemi, Bahar & Reda, ICCAD'15): dynamic-range
//!   unbiased truncation to `k` significant bits. DRUM-6's published
//!   error (MRE ≈ 1.47%, near-zero mean) is reproduced by
//!   `examples/characterize_multipliers.rs` and pinned by tests.
//! * [`Mitchell`] — Mitchell's logarithmic multiplier (1962), the
//!   classic log-domain approximation (biased negative).
//! * [`Truncation`] — static low-bit truncation (the naive baseline).
//! * [`GaussianModel`] — the paper's own *simulation* model: exact
//!   product times `(1 + sigma*eps)` from the shared Threefry stream.
//!   Comparing its statistics against the bit-accurate designs is what
//!   justifies (or indicts) the paper's modelling shortcut.
//!
//! Floating-point relevance: an f32/f16 multiply is an exact exponent
//! add plus a mantissa multiply, so the *relative* error of the mantissa
//! multiplier equals the relative error of the float product. The
//! [`OperandDist::Mantissa`] distribution (uniform over `[2^23, 2^24)`)
//! therefore characterizes exactly the error a CNN training MAC would
//! see — this is the bridge between these integer designs and the
//! Gaussian sigma fed to the compiled graphs.
//!
//! ## Batched simulation (the fast path)
//!
//! [`Multiplier::mul`] through a `Box<dyn Multiplier>` costs one
//! virtual call per product; at characterization scale (10^5..10^9
//! multiplies) that dominates. [`Multiplier::mul_batch`] amortizes the
//! dispatch to one virtual call per *slice*: default trait methods are
//! monomorphized per implementing type, so inside the batch body
//! `self.mul` is statically dispatched, inlined and auto-vectorized.
//! Designs whose loop benefits from restructuring (hoisted constants,
//! up-front noise-counter reservation) override `mul_batch`; the rest
//! keep the default, which is already the monomorphized loop. Either
//! way the batch path is bit-identical to the scalar path
//! (`tests/mult_batch.rs` pins this per design × operand
//! distribution). With the `simd` cargo feature the hot designs'
//! batch loops and the prepared GEMM's inner chains additionally route
//! through explicit vector kernels ([`simd`]) — same bits, pinned by
//! `tests/simd_parity.rs`.
//!
//! [`LutMultiplier`] is the ApproxTrain-style (arXiv:2209.04161)
//! lookup-table backend: it tabulates any design over a configurable
//! operand width (e.g. 8×8 or 12×12) and serves products with one load
//! plus two leading-one reductions. It is bit-identical to the wrapped
//! design whenever both operands fit the table width, and for
//! dynamic-range designs that only inspect the top bits (DRUM-k with
//! `k < bits`, strictly) over the *full* 32-bit range; for other
//! designs on wider operands it is the same leading-one truncation
//! ApproxTrain's mantissa LUTs apply.
//!
//! ## Parallelism & determinism
//!
//! [`characterize`] is a chunked parallel reduction: the sample stream
//! is split into fixed 2^16-sample chunks, each chunk draws from its
//! own seed-derived RNG, and per-chunk Welford accumulators merge with
//! the parallel-variance formula *in chunk order*. The schedule depends
//! only on `(n, seed)` — never on the worker count — so results are
//! bit-reproducible across thread counts for all stateless designs.
//! ([`GaussianModel`] draws from an internal atomic noise counter; its
//! batched statistics are reproducible for a fresh instance because the
//! counter range is consumed exactly once, but per-sample pairing is
//! thread-order dependent, so only its aggregate stats — not per-call
//! products — are stable under parallel characterization.)
//! [`approx_matmul`] runs the same bit-accurate multipliers over real
//! GEMM shapes through the decompose-once blocked kernel (operands
//! prepared into [`PreparedMatrix`] planes, input-derived row-block
//! parallelism) — deterministic at any worker count and bit-identical
//! to the scalar [`approx_matmul_reference`] walk.

mod broken_array;
pub mod cast;
mod drum;
mod gaussian;
mod lut;
mod matmul;
mod mitchell;
mod prepared;
mod roba;
mod spec;
mod stats;
mod truncation;

pub mod signed;
#[cfg(feature = "simd")]
pub mod simd;

pub use broken_array::BrokenArray;
pub use drum::Drum;
pub use gaussian::GaussianModel;
pub use lut::LutMultiplier;
pub use matmul::{
    approx_matmul, approx_matmul_nt, approx_matmul_prepared, approx_matmul_reference,
    approx_matmul_tn, approx_mul_f32, characterize_matmul, characterize_matmul_set,
    gemm_row_block, GemmOutput, GEMM_ROW_BLOCK,
};
pub use prepared::PreparedMatrix;
pub use mitchell::Mitchell;
pub use roba::Roba;
pub use signed::SignedMultiplier;
pub use spec::MultSpec;
pub use stats::{characterize, characterize_threads, ErrorStats, OperandDist};
pub use truncation::Truncation;

use anyhow::{bail, Result};

/// An (approximate) unsigned integer multiplier.
pub trait Multiplier: Send + Sync {
    /// Design name, e.g. `drum6`.
    fn name(&self) -> String;

    /// Approximate product of two unsigned operands.
    fn mul(&self, a: u32, b: u32) -> u64;

    /// Exact reference for error accounting. This is a convenience,
    /// not a customization point: the characterization harnesses
    /// ([`characterize`], [`approx_matmul`]) compute the reference
    /// inline as `a as u64 * b as u64` on their hot paths, so an
    /// override would not be honored there. Do not override.
    fn exact(&self, a: u32, b: u32) -> u64 {
        a as u64 * b as u64
    }

    /// Signed relative error of one product (0 when the exact product
    /// is 0, matching the MRE definition's implicit exclusion). Like
    /// [`Multiplier::exact`], the batched harnesses inline this
    /// definition rather than dispatching through it.
    fn relative_error(&self, a: u32, b: u32) -> f64 {
        let exact = self.exact(a, b);
        if exact == 0 {
            return 0.0;
        }
        (self.mul(a, b) as f64 - exact as f64) / exact as f64
    }

    /// Approximate products of paired slices: `out[i] = mul(a[i], b[i])`.
    ///
    /// This is the fast path: one virtual call per slice instead of one
    /// per element. Default trait methods monomorphize per implementing
    /// type, so this default body dispatches `self.mul` statically
    /// inside the loop — most designs need nothing more. Override only
    /// to restructure the loop (e.g. [`Truncation`] hoists its mask,
    /// [`GaussianModel`] reserves its noise-counter range up front);
    /// overrides must stay bit-identical to `mul` —
    /// `tests/mult_batch.rs` enforces this.
    ///
    /// # Panics
    /// Panics when the three slices differ in length.
    fn mul_batch(&self, a: &[u32], b: &[u32], out: &mut [u64]) {
        check_batch_lens(a, b, out);
        for ((&x, &y), o) in a.iter().zip(b).zip(out.iter_mut()) {
            *o = self.mul(x, y);
        }
    }

    /// The explicit-SIMD GEMM kernel descriptor for this design, when
    /// one exists (`simd` feature only). `None` — the default — keeps
    /// the prepared GEMM on the scalar-batch chain engine.
    /// Implementations must be bit-identical to `mul` over the
    /// mantissa domain; `tests/simd_parity.rs` pins GEMM outputs
    /// against the scalar oracles under the feature.
    #[cfg(feature = "simd")]
    fn simd_kernel(&self) -> Option<simd::UnsignedKernel<'_>> {
        None
    }
}

/// Shared length guard for `mul_batch` implementations.
#[inline]
pub(crate) fn check_batch_lens(a: &[u32], b: &[u32], out: &[u64]) {
    assert!(
        a.len() == b.len() && a.len() == out.len(),
        "mul_batch: slice lengths differ ({}, {}, {})",
        a.len(),
        b.len(),
        out.len()
    );
}

/// Exact multiplier (the paper's second training phase).
#[derive(Debug, Clone, Copy, Default)]
pub struct Exact;

impl Multiplier for Exact {
    fn name(&self) -> String {
        "exact".into()
    }

    fn mul(&self, a: u32, b: u32) -> u64 {
        a as u64 * b as u64
    }
    // `mul_batch` default: already a monomorphized widening-multiply
    // loop for this impl.

    #[cfg(feature = "simd")]
    fn simd_kernel(&self) -> Option<simd::UnsignedKernel<'_>> {
        Some(simd::UnsignedKernel::Exact)
    }
}

/// Build a multiplier from a spec string: `exact`, `drum<k>`,
/// `mitchell`, `roba`, `bam<d>`, `trunc<k>`, `gauss<sigma-percent>`
/// (or the training grammar's canonical alias `gaussian:<sigma>`, a
/// fraction), or `lut<bits>:<inner>` for the LUT-accelerated backend
/// of any of the above (e.g. `lut8:drum6`). Signed designs
/// (`sdrum<k>`, `booth<k>`, `sroba`, `slut<bits>:<inner>`) live in
/// [`signed::by_name`].
pub fn by_name(spec: &str) -> Result<Box<dyn Multiplier>> {
    if let Some(rest) = spec.strip_prefix("lut") {
        if let Some((bits, inner)) = rest.split_once(':') {
            let bits: u32 = bits.parse()?;
            let inner = by_name(inner)?;
            return Ok(Box::new(LutMultiplier::new(inner.as_ref(), bits)?));
        }
    }
    if spec == "exact" {
        return Ok(Box::new(Exact));
    }
    if spec == "mitchell" {
        return Ok(Box::new(Mitchell));
    }
    if spec == "roba" {
        return Ok(Box::new(Roba));
    }
    if let Some(d) = spec.strip_prefix("bam") {
        let d: u32 = d.parse()?;
        return Ok(Box::new(BrokenArray::new(d)?));
    }
    if let Some(k) = spec.strip_prefix("drum") {
        let k: u32 = k.parse()?;
        return Ok(Box::new(Drum::new(k)?));
    }
    if let Some(k) = spec.strip_prefix("trunc") {
        let k: u32 = k.parse()?;
        return Ok(Box::new(Truncation::new(k)?));
    }
    // `gaussian:<sigma>` / `gauss:<sigma>` are the training grammar's
    // (MultSpec) spelling, sigma as a fraction; accepted here too so
    // the two grammars agree on the canonical aliases. `gauss<pct>` is
    // this grammar's historical percent form.
    if let Some(v) = spec
        .strip_prefix("gaussian:")
        .or_else(|| spec.strip_prefix("gauss:"))
    {
        let sigma: f64 = v.parse()?;
        // Same bound MultSpec::parse applies — the aliases really are
        // shared, rejections included (NaN fails the range test too).
        if !(0.0..1.0).contains(&sigma) {
            bail!("gaussian sigma {sigma} out of sane range [0, 1)");
        }
        return Ok(Box::new(GaussianModel::new(sigma, 0)));
    }
    if let Some(p) = spec.strip_prefix("gauss") {
        let pct: f64 = p.parse()?;
        return Ok(Box::new(GaussianModel::new(pct / 100.0, 0)));
    }
    bail!(
        "unknown multiplier spec {spec:?} (expected exact | drum<k> | mitchell \
         | roba | bam<d> | trunc<k> | gauss<pct> | gaussian:<sigma> | \
         lut<bits>:<inner>; signed designs — sdrum<k> | booth<k> | sroba | \
         slut<bits>:<inner> — are built by mult::signed::by_name, and training \
         runs parse specs with MultSpec::parse)"
    )
}

/// A built GEMM design: the product multiplier a training run's spec
/// resolves to, in whichever operand domain it is published for.
/// Unsigned designs run the sign-externalized mantissa pipeline;
/// signed designs run the [`signed`] pipeline, where the operand signs
/// go **through** the multiplier.
pub enum GemmDesign {
    Unsigned(Box<dyn Multiplier>),
    Signed(Box<dyn SignedMultiplier>),
}

impl GemmDesign {
    /// Build from a design spec string: signed-grammar specs (decided
    /// syntactically — the prefixes never overlap) resolve through
    /// [`signed::by_name`], everything else through [`by_name`].
    pub fn by_name(spec: &str) -> Result<GemmDesign> {
        if signed::is_signed_spec(spec) {
            return Ok(GemmDesign::Signed(signed::by_name(spec)?));
        }
        Ok(GemmDesign::Unsigned(by_name(spec)?))
    }

    /// Design name, e.g. `drum6` or `sdrum6`.
    pub fn name(&self) -> String {
        match self {
            GemmDesign::Unsigned(m) => m.name(),
            GemmDesign::Signed(m) => m.name(),
        }
    }

    /// Borrowed dispatch handle for GEMM call sites.
    pub fn mode(&self) -> GemmMode<'_> {
        match self {
            GemmDesign::Unsigned(m) => GemmMode::Unsigned(m.as_ref()),
            GemmDesign::Signed(m) => GemmMode::Signed(m.as_ref()),
        }
    }
}

/// A borrowed [`GemmDesign`]: the value GEMM call sites thread through
/// one training step.
#[derive(Clone, Copy)]
pub enum GemmMode<'a> {
    Unsigned(&'a dyn Multiplier),
    Signed(&'a dyn SignedMultiplier),
}

impl GemmMode<'_> {
    /// Whether operands must carry the signed-mantissa plane
    /// ([`PreparedMatrix::with_signed_mantissas`]).
    pub fn is_signed(self) -> bool {
        matches!(self, GemmMode::Signed(_))
    }

    /// Run the blocked prepared kernel of this mode's pipeline —
    /// [`approx_matmul_prepared`] or
    /// [`signed::approx_matmul_prepared_signed`] — with the same fused
    /// epilogues and determinism contract.
    pub fn matmul_prepared(
        self,
        a: &PreparedMatrix,
        b_packed: &PreparedMatrix,
        bias: Option<&[f32]>,
        with_col_sums: bool,
    ) -> Result<GemmOutput> {
        match self {
            GemmMode::Unsigned(m) => {
                approx_matmul_prepared(m, a, b_packed, bias, with_col_sums)
            }
            GemmMode::Signed(m) => signed::approx_matmul_prepared_signed(
                m,
                a,
                b_packed,
                bias,
                with_col_sums,
            ),
        }
    }
}

/// The design set the characterization harness sweeps by default.
pub fn standard_designs() -> Vec<Box<dyn Multiplier>> {
    vec![
        Box::new(Exact),
        Box::new(Drum::new(4).unwrap()),
        Box::new(Drum::new(6).unwrap()),
        Box::new(Drum::new(8).unwrap()),
        Box::new(Mitchell),
        Box::new(Roba),
        Box::new(BrokenArray::new(8).unwrap()),
        Box::new(BrokenArray::new(12).unwrap()),
        Box::new(Truncation::new(8).unwrap()),
        Box::new(Truncation::new(12).unwrap()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_is_exact() {
        let m = Exact;
        assert_eq!(m.mul(0, 0), 0);
        assert_eq!(m.mul(u32::MAX, u32::MAX), u32::MAX as u64 * u32::MAX as u64);
        assert_eq!(m.relative_error(12345, 6789), 0.0);
    }

    #[test]
    fn by_name_parses() {
        assert_eq!(by_name("exact").unwrap().name(), "exact");
        assert_eq!(by_name("drum6").unwrap().name(), "drum6");
        assert_eq!(by_name("trunc8").unwrap().name(), "trunc8");
        assert_eq!(by_name("mitchell").unwrap().name(), "mitchell");
        assert_eq!(by_name("roba").unwrap().name(), "roba");
        assert_eq!(by_name("bam8").unwrap().name(), "bam8");
        assert_eq!(by_name("lut8:drum6").unwrap().name(), "lut8:drum6");
        assert!(by_name("drum").is_err());
        assert!(by_name("bogus").is_err());
        assert!(by_name("lut99:drum6").is_err());
        assert!(by_name("lut8:bogus").is_err());
    }

    #[test]
    fn gaussian_aliases_are_shared_with_the_training_grammar() {
        // `gauss4.5` (percent) and `gaussian:0.045` (fraction) build
        // the same model: the two grammars agree on the canonical
        // alias instead of each rejecting the other's spelling.
        assert_eq!(by_name("gauss4.5").unwrap().name(), "gauss0.0450");
        assert_eq!(by_name("gaussian:0.045").unwrap().name(), "gauss0.0450");
        assert_eq!(by_name("gauss:0.045").unwrap().name(), "gauss0.0450");
        assert!(by_name("gaussian:x").is_err());
        // The alias carries MultSpec's range check with it.
        assert!(by_name("gaussian:1.5").is_err());
        assert!(by_name("gaussian:-0.1").is_err());
        assert!(by_name("gaussian:nan").is_err());
        // The unknown-spec error names the signed and training grammars.
        let err = by_name("sdrum6").unwrap_err().to_string();
        assert!(err.contains("mult::signed::by_name"), "{err}");
        assert!(err.contains("MultSpec::parse"), "{err}");
    }

    #[test]
    fn gemm_design_resolves_by_domain() {
        assert_eq!(GemmDesign::by_name("drum6").unwrap().name(), "drum6");
        assert_eq!(GemmDesign::by_name("sdrum6").unwrap().name(), "sdrum6");
        assert!(matches!(
            GemmDesign::by_name("booth8").unwrap().mode(),
            GemmMode::Signed(_)
        ));
        assert!(matches!(
            GemmDesign::by_name("mitchell").unwrap().mode(),
            GemmMode::Unsigned(_)
        ));
        assert!(GemmDesign::by_name("bogus").is_err());
    }

    #[test]
    fn default_mul_batch_matches_scalar() {
        let m = by_name("drum6").unwrap();
        let a = [0u32, 1, 77, 0xFFFF, 0xFFFF_FFFF];
        let b = [5u32, 0, 123_456, 0xABCD, 3];
        let mut out = [0u64; 5];
        m.mul_batch(&a, &b, &mut out);
        for i in 0..a.len() {
            assert_eq!(out[i], m.mul(a[i], b[i]));
        }
    }

    #[test]
    #[should_panic(expected = "slice lengths differ")]
    fn mul_batch_length_mismatch_panics() {
        let mut out = [0u64; 2];
        Exact.mul_batch(&[1, 2, 3], &[4, 5, 6], &mut out);
    }

    #[test]
    fn relative_error_zero_product() {
        let m = by_name("drum6").unwrap();
        assert_eq!(m.relative_error(0, 12345), 0.0);
    }
}
