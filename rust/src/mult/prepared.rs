//! [`PreparedMatrix`]: one-time decomposition of a GEMM operand into
//! packed sign / exponent / mantissa planes.
//!
//! The original `approx_matmul` kernel decomposed both f32 operands of
//! *every scalar product* — so the weight matrix of a layer was
//! decomposed `rows × cols` times per GEMM. Preparing an operand once
//! (one decomposition per element, laid out so the kernel streams the
//! planes contiguously) turns that quadratic re-work into a linear
//! setup pass, which is what makes the blocked kernel in
//! [`super::matmul`] fast. ApproxTrain (arXiv:2209.04161) applies the
//! same packing idea to its simulated-multiplier GEMM.
//!
//! Encoding, per element:
//!
//! * **normal** — `exp` holds the biased exponent (1..=254), `mant` the
//!   24-bit mantissa with the implicit leading one, `sign` the sign
//!   bit;
//! * **flushed** (zero or subnormal) — `exp == EXP_FLUSHED`; the
//!   integer designs have no subnormal path, so these contribute a
//!   signed zero to a dot product. The raw f32 bits are preserved in
//!   `mant` so a chain partner that is non-finite still sees the true
//!   value (`inf * subnormal` is `±inf`, not `inf * 0 = NaN`);
//! * **non-finite** (inf/NaN) — `exp == EXP_NONFINITE`, with the raw
//!   f32 bits preserved in `mant` so the kernel can fall back to the
//!   native product.
//!
//! A `PreparedMatrix` is layout-agnostic: [`PreparedMatrix::prepare_strided`]
//! reads the source through arbitrary row/column strides, so the same
//! type serves row-major A operands, column-packed B panels, and the
//! transposed-operand GEMM variants without materializing an f32
//! transpose. [`PreparedMatrix::transposed`] re-packs the planes (a
//! copy, **not** a re-decomposition) when a second layout of the same
//! matrix is needed — e.g. the weight matrix prepared once per training
//! step and used by both the forward `A·W` and the backward `dY·Wᵀ`.
//!
//! ## Plane layout and vector loads
//!
//! Each plane (`signs: Vec<u8>`, `exps: Vec<i32>`, `mants: Vec<u32>`,
//! and optionally `smants: Vec<i32>`) is one contiguous row-major
//! allocation; a k-chain is a contiguous run of each plane, which is
//! exactly what the `simd`-feature chain microkernel
//! (`crate::mult::simd`) relies on: it issues unaligned vector loads
//! (`Simd::from_slice`) straight off the row slices returned by
//! [`PreparedMatrix::row`] / [`PreparedMatrix::smant_row`], with no
//! gather or re-pack step. `Vec`'s natural alignment is sufficient —
//! the kernels use unaligned loads throughout — so no over-alignment
//! is applied; keeping the planes as plain `Vec`s also keeps the
//! feature-off layout byte-for-byte identical.

use anyhow::{bail, Result};

/// `exp` sentinel: zero/subnormal operand, flushed to signed zero.
pub(crate) const EXP_FLUSHED: i32 = i32::MIN;
/// `exp` sentinel: inf/NaN operand; `mant` holds the raw f32 bits.
pub(crate) const EXP_NONFINITE: i32 = i32::MAX;

/// Reconstruct the original f32 of one prepared element (flushed and
/// non-finite elements carry their raw bits in `mant`).
#[inline]
pub(crate) fn element_value(sign: u8, exp: i32, mant: u32) -> f32 {
    match exp {
        EXP_NONFINITE | EXP_FLUSHED => f32::from_bits(mant),
        e => f32::from_bits(
            ((sign as u32) << 31) | ((e as u32) << 23) | (mant & 0x007F_FFFF),
        ),
    }
}

/// A `[rows × cols]` matrix decomposed into contiguous row-major
/// sign / exponent / mantissa planes (see the module docs for the
/// per-element encoding).
///
/// For the **signed** GEMM path ([`super::signed`]) the matrix can
/// additionally carry a signed-mantissa plane
/// ([`PreparedMatrix::with_signed_mantissas`]): `±(1.m × 2^23)` as
/// two's-complement `i32`, the operand layout a
/// [`super::signed::SignedMultiplier`] consumes directly — the sign
/// travels *into* the design instead of being re-applied after it.
pub struct PreparedMatrix {
    rows: usize,
    cols: usize,
    sign: Vec<u8>,
    exp: Vec<i32>,
    mant: Vec<u32>,
    /// Signed mantissas (`0` for flushed/non-finite elements), present
    /// only when prepared for the signed kernel.
    smant: Option<Vec<i32>>,
}

impl PreparedMatrix {
    /// Prepare a row-major `[rows × cols]` f32 matrix.
    pub fn prepare(data: &[f32], rows: usize, cols: usize) -> Result<Self> {
        Self::prepare_strided(data, rows, cols, cols, 1)
    }

    /// Prepare the logical `[rows × cols]` matrix whose element `(r, c)`
    /// lives at `data[r*row_stride + c*col_stride]` — one decomposition
    /// per element, whatever the source layout (row-major, transposed,
    /// or a column-packed panel view).
    pub fn prepare_strided(
        data: &[f32],
        rows: usize,
        cols: usize,
        row_stride: usize,
        col_stride: usize,
    ) -> Result<Self> {
        let n = rows * cols;
        if n > 0 {
            let last = (rows - 1) * row_stride + (cols - 1) * col_stride;
            if last >= data.len() {
                bail!(
                    "prepare_strided: [{rows}x{cols}] with strides \
                     ({row_stride}, {col_stride}) needs {} elements, got {}",
                    last + 1,
                    data.len()
                );
            }
        }
        let mut sign = vec![0u8; n];
        let mut exp = vec![0i32; n];
        let mut mant = vec![0u32; n];
        for r in 0..rows {
            for c in 0..cols {
                let x = data[r * row_stride + c * col_stride];
                let i = r * cols + c;
                let bits = x.to_bits();
                if !x.is_finite() {
                    exp[i] = EXP_NONFINITE;
                    mant[i] = bits;
                    continue;
                }
                let e = ((bits >> 23) & 0xFF) as i32;
                sign[i] = (bits >> 31) as u8;
                if e == 0 {
                    exp[i] = EXP_FLUSHED;
                    mant[i] = bits; // raw bits: exact non-finite fallback
                } else {
                    exp[i] = e;
                    mant[i] = (bits & 0x007F_FFFF) | 0x0080_0000;
                }
            }
        }
        Ok(PreparedMatrix { rows, cols, sign, exp, mant, smant: None })
    }

    /// Derive the signed-mantissa plane the signed GEMM kernel
    /// consumes: `±mant` for normal elements, `0` for flushed and
    /// non-finite ones (flushed terms are skipped; non-finite terms
    /// take the raw-bits fallback, never the plane). A pure plane
    /// derivation — the sign/exp/mant planes are untouched, so the
    /// same matrix still serves the unsigned kernel bit-identically.
    pub fn with_signed_mantissas(mut self) -> Self {
        let smant = self
            .exp
            .iter()
            .zip(self.sign.iter().zip(&self.mant))
            .map(|(&e, (&s, &m))| match e {
                EXP_FLUSHED | EXP_NONFINITE => 0i32,
                _ if s != 0 => -(m as i32),
                _ => m as i32,
            })
            .collect();
        self.smant = Some(smant);
        self
    }

    /// Whether the signed-mantissa plane is present (the signed kernel
    /// requires it; see [`PreparedMatrix::with_signed_mantissas`]).
    pub fn has_signed_mantissas(&self) -> bool {
        self.smant.is_some()
    }

    /// The same matrix with rows and columns swapped — a plane re-pack
    /// (pure copies), **not** a re-decomposition. Carries the
    /// signed-mantissa plane along when present.
    pub fn transposed(&self) -> PreparedMatrix {
        let (rows, cols) = (self.cols, self.rows);
        let n = rows * cols;
        let mut sign = vec![0u8; n];
        let mut exp = vec![0i32; n];
        let mut mant = vec![0u32; n];
        let mut smant = self.smant.as_ref().map(|_| vec![0i32; n]);
        for r in 0..self.rows {
            for c in 0..self.cols {
                let src = r * self.cols + c;
                let dst = c * self.rows + r;
                sign[dst] = self.sign[src];
                exp[dst] = self.exp[src];
                mant[dst] = self.mant[src];
                if let (Some(d), Some(s)) = (smant.as_mut(), self.smant.as_ref()) {
                    d[dst] = s[src];
                }
            }
        }
        PreparedMatrix { rows, cols, sign, exp, mant, smant }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The three plane slices of row `r` (each of length `cols`).
    #[inline]
    pub(crate) fn row(&self, r: usize) -> (&[u8], &[i32], &[u32]) {
        let s = r * self.cols;
        let e = s + self.cols;
        (&self.sign[s..e], &self.exp[s..e], &self.mant[s..e])
    }

    /// The signed-mantissa slice of row `r`.
    ///
    /// # Panics
    /// Panics when the plane is absent; the signed kernel guards with
    /// [`PreparedMatrix::has_signed_mantissas`] at entry.
    #[inline]
    pub(crate) fn smant_row(&self, r: usize) -> &[i32] {
        let s = r * self.cols;
        &self.smant.as_ref().expect("signed-mantissa plane")[s..s + self.cols]
    }

    /// Reconstructed f32 of element `(r, c)` (tests / non-finite paths).
    pub(crate) fn value(&self, r: usize, c: usize) -> f32 {
        let i = r * self.cols + c;
        element_value(self.sign[i], self.exp[i], self.mant[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn roundtrips_normals_zeros_subnormals_and_nonfinite() {
        let vals = [
            1.0f32,
            -2.5,
            0.0,
            -0.0,
            f32::MIN_POSITIVE / 2.0, // subnormal -> flushed
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            3.4e38,
            -1.0e-38,
        ];
        let p = PreparedMatrix::prepare(&vals, 2, 5).unwrap();
        for r in 0..2 {
            for c in 0..5 {
                // Every class — normal, zero, subnormal (flushed but
                // bits kept), inf, NaN — reconstructs bit-exactly.
                let x = vals[r * 5 + c];
                assert_eq!(p.value(r, c).to_bits(), x.to_bits(), "{x}");
            }
        }
    }

    #[test]
    fn strided_prepare_matches_explicit_transpose() {
        let mut rng = Xoshiro256::new(11);
        let (rows, cols) = (7usize, 5usize);
        let data: Vec<f32> =
            (0..rows * cols).map(|_| rng.next_f32() - 0.5).collect();
        // data is [rows x cols] row-major; read it as its transpose.
        let t = PreparedMatrix::prepare_strided(&data, cols, rows, 1, cols).unwrap();
        let p = PreparedMatrix::prepare(&data, rows, cols).unwrap();
        assert_eq!(t.rows(), cols);
        for r in 0..rows {
            for c in 0..cols {
                assert_eq!(t.value(c, r).to_bits(), p.value(r, c).to_bits());
            }
        }
        // transposed() re-packs to the same planes.
        let tt = p.transposed();
        for r in 0..cols {
            for c in 0..rows {
                assert_eq!(tt.value(r, c).to_bits(), t.value(r, c).to_bits());
            }
        }
    }

    #[test]
    fn signed_mantissa_plane_classifies_and_transposes() {
        let vals = [
            1.5f32,          // +: +(1.1 << 23)
            -2.5,            // -: negative mantissa
            0.0,             // flushed -> 0
            f32::NAN,        // non-finite -> 0
            -1.0e-41,        // subnormal -> flushed -> 0
            -1.0,            // -: exactly -(1 << 23)
        ];
        let p = PreparedMatrix::prepare(&vals, 2, 3).unwrap();
        assert!(!p.has_signed_mantissas());
        let p = p.with_signed_mantissas();
        assert!(p.has_signed_mantissas());
        assert_eq!(p.smant_row(0), &[0x00C0_0000, -0x00A0_0000, 0]);
        assert_eq!(p.smant_row(1), &[0, 0, -0x0080_0000]);
        // Unsigned planes untouched; the transpose carries the plane.
        assert_eq!(p.value(0, 1).to_bits(), (-2.5f32).to_bits());
        let t = p.transposed();
        assert!(t.has_signed_mantissas());
        assert_eq!(t.smant_row(1), &[-0x00A0_0000, 0]);
        assert_eq!(t.smant_row(2), &[0, -0x0080_0000]);
    }

    #[test]
    fn prepare_rejects_short_slices() {
        assert!(PreparedMatrix::prepare(&[0.0; 5], 2, 3).is_err());
        assert!(PreparedMatrix::prepare_strided(&[0.0; 5], 2, 3, 3, 1).is_err());
        // Empty shapes are fine.
        assert!(PreparedMatrix::prepare(&[], 0, 3).is_ok());
    }
}
