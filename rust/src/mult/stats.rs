//! Error characterization harness: drive a [`Multiplier`] over an
//! operand distribution and accumulate MRE / SD / bias / extrema.
//! This regenerates the error columns of the cited design papers (and
//! the mapping in the paper's §III).
//!
//! Since PR 1 this is a chunked parallel reduction over the batched
//! [`Multiplier::mul_batch`] fast path: the sample stream splits into
//! fixed [`CHUNK_SAMPLES`]-sized chunks, each chunk draws operands from
//! its own seed-derived RNG and runs a local Welford accumulator, and
//! chunk accumulators merge **in chunk order** with the Chan et al.
//! parallel-variance formula. The chunk schedule depends only on
//! `(n, seed)`, so results are bit-reproducible at any worker count
//! (pinned by `characterize_threads` equality tests).

use crate::parallel;
use crate::rng::{SplitMix64, Xoshiro256};

use super::Multiplier;

/// Samples per scheduling chunk. Fixed (not derived from the worker
/// count) so the sample → chunk assignment — and therefore the result —
/// is identical at any parallelism level.
pub const CHUNK_SAMPLES: u64 = 1 << 16;

/// Operand/product staging length: big enough to amortize the virtual
/// `mul_batch` call, small enough to stay cache-resident.
const BATCH: usize = 4096;

/// Operand distributions for characterization.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OperandDist {
    /// Uniform over the full 16-bit range `[1, 2^16)` — the distribution
    /// the DRUM paper reports against.
    Uniform16,
    /// Uniform over `[1, 2^32)`.
    Uniform32,
    /// Uniform over `[2^23, 2^24)` — normalized f32 mantissas: the
    /// distribution a floating-point CNN MAC actually feeds the
    /// mantissa multiplier.
    Mantissa,
    /// Low-magnitude operands `[1, 2^8)` — stresses designs whose error
    /// depends on operand range (truncation collapses here).
    Small,
}

impl OperandDist {
    pub fn sample(self, rng: &mut Xoshiro256) -> u32 {
        match self {
            OperandDist::Uniform16 => 1 + rng.next_below(65_535) as u32,
            OperandDist::Uniform32 => {
                let v = rng.next_u32();
                if v == 0 {
                    1
                } else {
                    v
                }
            }
            OperandDist::Mantissa => (1 << 23) + rng.next_below(1 << 23) as u32,
            OperandDist::Small => 1 + rng.next_below(255) as u32,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            OperandDist::Uniform16 => "uniform16",
            OperandDist::Uniform32 => "uniform32",
            OperandDist::Mantissa => "mantissa",
            OperandDist::Small => "small",
        }
    }

    /// Every distribution, for sweeps and property tests.
    pub fn all() -> [OperandDist; 4] {
        [
            OperandDist::Uniform16,
            OperandDist::Uniform32,
            OperandDist::Mantissa,
            OperandDist::Small,
        ]
    }
}

/// Streaming error statistics of a multiplier design.
#[derive(Debug, Clone, Copy)]
pub struct ErrorStats {
    /// Mean of |relative error| — the paper's MRE, equation (1).
    pub mre: f64,
    /// Standard deviation of the *signed* relative error — the paper's SD.
    pub sd: f64,
    /// Mean signed relative error (bias; ~0 for unbiased designs).
    pub mean_re: f64,
    pub min_re: f64,
    pub max_re: f64,
    pub samples: u64,
}

impl ErrorStats {
    /// `MRE / SD` — equals sqrt(2/pi) ≈ 0.798 iff the error is
    /// zero-mean Gaussian (the identity behind the paper's Table II).
    pub fn gaussianity_ratio(&self) -> f64 {
        if self.sd == 0.0 {
            return 0.0;
        }
        self.mre / self.sd
    }
}

/// Mergeable Welford accumulator over signed relative error. Shared by
/// the characterization chunks and the GEMM comparison in
/// [`super::matmul`].
#[derive(Debug, Clone, Copy)]
pub(super) struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    abs_sum: f64,
    min_re: f64,
    max_re: f64,
}

impl Default for Welford {
    fn default() -> Self {
        Self::new()
    }
}

impl Welford {
    pub(super) fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            abs_sum: 0.0,
            min_re: f64::INFINITY,
            max_re: f64::NEG_INFINITY,
        }
    }

    pub(super) fn push(&mut self, re: f64) {
        self.n += 1;
        self.abs_sum += re.abs();
        let delta = re - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (re - self.mean);
        self.min_re = self.min_re.min(re);
        self.max_re = self.max_re.max(re);
    }

    /// Chan et al. parallel-variance merge. Called in a fixed order so
    /// the floating-point result is deterministic.
    pub(super) fn merge(self, other: Welford) -> Welford {
        if self.n == 0 {
            return other;
        }
        if other.n == 0 {
            return self;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        Welford {
            n,
            mean: self.mean + delta * (other.n as f64 / n as f64),
            m2: self.m2
                + other.m2
                + delta * delta * (self.n as f64 * other.n as f64 / n as f64),
            abs_sum: self.abs_sum + other.abs_sum,
            min_re: self.min_re.min(other.min_re),
            max_re: self.max_re.max(other.max_re),
        }
    }

    pub(super) fn finish(self) -> ErrorStats {
        if self.n == 0 {
            return ErrorStats {
                mre: 0.0,
                sd: 0.0,
                mean_re: 0.0,
                min_re: 0.0,
                max_re: 0.0,
                samples: 0,
            };
        }
        ErrorStats {
            mre: self.abs_sum / self.n as f64,
            sd: (self.m2 / self.n as f64).sqrt(),
            mean_re: self.mean,
            min_re: self.min_re,
            max_re: self.max_re,
            samples: self.n,
        }
    }
}

/// Decorrelated per-chunk RNG seed — one SplitMix64 step over
/// `(seed, chunk)`.
fn chunk_seed(seed: u64, chunk: u64) -> u64 {
    SplitMix64::new(seed ^ chunk.wrapping_mul(0xA076_1D64_78BD_642F)).next_u64()
}

/// One chunk: draw `len` operand pairs, run the batched fast path, and
/// accumulate locally.
fn run_chunk(m: &dyn Multiplier, dist: OperandDist, len: u64, seed: u64) -> Welford {
    let mut rng = Xoshiro256::new(seed);
    let mut acc = Welford::new();
    let mut a = [0u32; BATCH];
    let mut b = [0u32; BATCH];
    let mut out = [0u64; BATCH];
    let mut left = len;
    while left > 0 {
        let k = left.min(BATCH as u64) as usize;
        for i in 0..k {
            a[i] = dist.sample(&mut rng);
            b[i] = dist.sample(&mut rng);
        }
        m.mul_batch(&a[..k], &b[..k], &mut out[..k]);
        for i in 0..k {
            // Exact reference inline (all designs use the default
            // `exact`); 0 maps to 0 error per the MRE definition.
            let exact = a[i] as u64 * b[i] as u64;
            let re = if exact == 0 {
                0.0
            } else {
                (out[i] as f64 - exact as f64) / exact as f64
            };
            acc.push(re);
        }
        left -= k as u64;
    }
    acc
}

/// Characterize `m` over `n` random operand pairs from `dist`, in
/// parallel over [`parallel::max_threads`] workers. Deterministic in
/// `(n, seed)` for stateless designs regardless of worker count; see
/// the module docs for the [`super::GaussianModel`] caveat.
pub fn characterize(m: &dyn Multiplier, dist: OperandDist, n: u64, seed: u64) -> ErrorStats {
    characterize_threads(m, dist, n, seed, parallel::max_threads())
}

/// [`characterize`] with an explicit worker count (1 = fully
/// sequential on the calling thread). Any two worker counts produce
/// bit-identical results for stateless designs — the schedule is fixed
/// by `(n, seed)`.
pub fn characterize_threads(
    m: &dyn Multiplier,
    dist: OperandDist,
    n: u64,
    seed: u64,
    threads: usize,
) -> ErrorStats {
    if n == 0 {
        return Welford::new().finish();
    }
    let chunks: Vec<(u64, u64)> = (0..n.div_ceil(CHUNK_SAMPLES))
        .map(|c| {
            let start = c * CHUNK_SAMPLES;
            (c, (n - start).min(CHUNK_SAMPLES))
        })
        .collect();
    let accs = parallel::par_map(&chunks, threads, |_, &(c, len)| {
        run_chunk(m, dist, len, chunk_seed(seed, c))
    });
    // Merge in chunk order — deterministic floating-point reduction.
    accs.into_iter().fold(Welford::new(), Welford::merge).finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mult::Exact;

    #[test]
    fn exact_has_zero_error() {
        let s = characterize(&Exact, OperandDist::Uniform16, 10_000, 1);
        assert_eq!(s.mre, 0.0);
        assert_eq!(s.sd, 0.0);
        assert_eq!(s.samples, 10_000);
    }

    #[test]
    fn deterministic_in_seed() {
        let d = crate::mult::Drum::new(6).unwrap();
        let a = characterize(&d, OperandDist::Mantissa, 5_000, 42);
        let b = characterize(&d, OperandDist::Mantissa, 5_000, 42);
        assert_eq!(a.mre, b.mre);
        assert_eq!(a.sd, b.sd);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        // Multi-chunk run (n > CHUNK_SAMPLES): sequential vs parallel
        // schedules must agree bit-for-bit.
        let d = crate::mult::Drum::new(6).unwrap();
        let seq = characterize_threads(&d, OperandDist::Uniform16, 200_000, 9, 1);
        let par = characterize_threads(&d, OperandDist::Uniform16, 200_000, 9, 8);
        assert_eq!(seq.mre, par.mre);
        assert_eq!(seq.sd, par.sd);
        assert_eq!(seq.mean_re, par.mean_re);
        assert_eq!(seq.min_re, par.min_re);
        assert_eq!(seq.max_re, par.max_re);
    }

    #[test]
    fn zero_samples_is_well_defined() {
        let s = characterize(&Exact, OperandDist::Small, 0, 3);
        assert_eq!(s.samples, 0);
        assert_eq!(s.mre, 0.0);
        assert_eq!(s.min_re, 0.0);
    }

    #[test]
    fn gaussianity_ratio_for_gaussian_model() {
        let g = crate::mult::GaussianModel::new(0.05, 3);
        let s = characterize(&g, OperandDist::Mantissa, 100_000, 4);
        assert!((s.gaussianity_ratio() - crate::HALF_NORMAL_MEAN).abs() < 0.02);
    }

    #[test]
    fn welford_merge_matches_streaming() {
        // Split-and-merge equals one streaming pass (up to fp noise).
        let xs: Vec<f64> = (0..1000).map(|i| ((i * 37) % 101) as f64 / 50.0 - 1.0).collect();
        let mut one = Welford::new();
        for &x in &xs {
            one.push(x);
        }
        let mut lo = Welford::new();
        let mut hi = Welford::new();
        for &x in &xs[..337] {
            lo.push(x);
        }
        for &x in &xs[337..] {
            hi.push(x);
        }
        let merged = lo.merge(hi).finish();
        let direct = one.finish();
        assert_eq!(merged.samples, direct.samples);
        assert!((merged.mean_re - direct.mean_re).abs() < 1e-12);
        assert!((merged.sd - direct.sd).abs() < 1e-12);
        assert_eq!(merged.min_re, direct.min_re);
        assert_eq!(merged.max_re, direct.max_re);
    }
}
