//! Error characterization harness: drive a [`Multiplier`] over an
//! operand distribution and accumulate MRE / SD / bias / extrema with
//! Welford's streaming algorithm. This regenerates the error columns of
//! the cited design papers (and the mapping in the paper's §III).

use crate::rng::Xoshiro256;

use super::Multiplier;

/// Operand distributions for characterization.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OperandDist {
    /// Uniform over the full 16-bit range `[1, 2^16)` — the distribution
    /// the DRUM paper reports against.
    Uniform16,
    /// Uniform over `[1, 2^32)`.
    Uniform32,
    /// Uniform over `[2^23, 2^24)` — normalized f32 mantissas: the
    /// distribution a floating-point CNN MAC actually feeds the
    /// mantissa multiplier.
    Mantissa,
    /// Low-magnitude operands `[1, 2^8)` — stresses designs whose error
    /// depends on operand range (truncation collapses here).
    Small,
}

impl OperandDist {
    pub fn sample(self, rng: &mut Xoshiro256) -> u32 {
        match self {
            OperandDist::Uniform16 => 1 + rng.next_below(65_535) as u32,
            OperandDist::Uniform32 => {
                let v = rng.next_u32();
                if v == 0 {
                    1
                } else {
                    v
                }
            }
            OperandDist::Mantissa => (1 << 23) + rng.next_below(1 << 23) as u32,
            OperandDist::Small => 1 + rng.next_below(255) as u32,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            OperandDist::Uniform16 => "uniform16",
            OperandDist::Uniform32 => "uniform32",
            OperandDist::Mantissa => "mantissa",
            OperandDist::Small => "small",
        }
    }
}

/// Streaming error statistics of a multiplier design.
#[derive(Debug, Clone, Copy)]
pub struct ErrorStats {
    /// Mean of |relative error| — the paper's MRE, equation (1).
    pub mre: f64,
    /// Standard deviation of the *signed* relative error — the paper's SD.
    pub sd: f64,
    /// Mean signed relative error (bias; ~0 for unbiased designs).
    pub mean_re: f64,
    pub min_re: f64,
    pub max_re: f64,
    pub samples: u64,
}

impl ErrorStats {
    /// `MRE / SD` — equals sqrt(2/pi) ≈ 0.798 iff the error is
    /// zero-mean Gaussian (the identity behind the paper's Table II).
    pub fn gaussianity_ratio(&self) -> f64 {
        if self.sd == 0.0 {
            return 0.0;
        }
        self.mre / self.sd
    }
}

/// Characterize `m` over `n` random operand pairs from `dist`.
pub fn characterize(
    m: &dyn Multiplier,
    dist: OperandDist,
    n: u64,
    seed: u64,
) -> ErrorStats {
    let mut rng = Xoshiro256::new(seed);
    let mut mean = 0.0f64; // Welford over signed relative error
    let mut m2 = 0.0f64;
    let mut abs_sum = 0.0f64;
    let (mut min_re, mut max_re) = (f64::INFINITY, f64::NEG_INFINITY);
    for i in 1..=n {
        let a = dist.sample(&mut rng);
        let b = dist.sample(&mut rng);
        let re = m.relative_error(a, b);
        abs_sum += re.abs();
        let delta = re - mean;
        mean += delta / i as f64;
        m2 += delta * (re - mean);
        min_re = min_re.min(re);
        max_re = max_re.max(re);
    }
    ErrorStats {
        mre: abs_sum / n as f64,
        sd: (m2 / n as f64).sqrt(),
        mean_re: mean,
        min_re,
        max_re,
        samples: n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mult::Exact;

    #[test]
    fn exact_has_zero_error() {
        let s = characterize(&Exact, OperandDist::Uniform16, 10_000, 1);
        assert_eq!(s.mre, 0.0);
        assert_eq!(s.sd, 0.0);
        assert_eq!(s.samples, 10_000);
    }

    #[test]
    fn deterministic_in_seed() {
        let d = crate::mult::Drum::new(6).unwrap();
        let a = characterize(&d, OperandDist::Mantissa, 5_000, 42);
        let b = characterize(&d, OperandDist::Mantissa, 5_000, 42);
        assert_eq!(a.mre, b.mre);
        assert_eq!(a.sd, b.sd);
    }

    #[test]
    fn gaussianity_ratio_for_gaussian_model() {
        let g = crate::mult::GaussianModel::new(0.05, 3);
        let s = characterize(&g, OperandDist::Mantissa, 100_000, 4);
        assert!((s.gaussianity_ratio() - crate::HALF_NORMAL_MEAN).abs() < 0.02);
    }
}
