//! DRUM: Dynamic Range Unbiased Multiplier (Hashemi, Bahar & Reda,
//! ICCAD 2015) — the design the paper maps to its Table II test case 2
//! (MRE ≈ 1.47%, SD ≈ 1.8%, +47% speed / −50% area / −59% power).
//!
//! Principle: for each operand, locate the leading one and keep only the
//! `k` most significant bits from there, **forcing the lowest kept bit
//! to 1**. The forced bit makes the truncation unbiased: discarded bits
//! average half their range, and `expected(truncated + forced LSB)`
//! equals the original expectation, so the error distribution is
//! near-zero-mean — exactly the property the paper's Gaussian model
//! assumes.

use anyhow::{bail, Result};

use super::{check_batch_lens, Multiplier};

/// Dynamic-range truncation of one operand: returns
/// `(approximated value, shift)` with `value < 2^k`. Free function so
/// both the method path and the hoisted batch loop share one body.
#[inline]
pub(super) fn reduce_k(v: u32, k: u32) -> (u32, u32) {
    if v == 0 {
        return (0, 0);
    }
    let msb = 31 - v.leading_zeros(); // position of leading one
    if msb < k {
        // Fits entirely: exact.
        return (v, 0);
    }
    let shift = msb + 1 - k;
    // Keep top-k bits, then force the lowest kept bit to 1
    // (the unbiasing trick).
    ((v >> shift) | 1, shift)
}

/// DRUM-k approximate multiplier.
#[derive(Debug, Clone, Copy)]
pub struct Drum {
    k: u32,
}

impl Drum {
    /// `k` in `[3, 32]` — the number of retained significant bits.
    pub fn new(k: u32) -> Result<Self> {
        if !(3..=32).contains(&k) {
            bail!("DRUM k must be in [3, 32], got {k}");
        }
        Ok(Drum { k })
    }

    /// The retained-bit count (the signed wrapper's kernel descriptor
    /// needs it).
    #[cfg(feature = "simd")]
    pub(crate) fn k(&self) -> u32 {
        self.k
    }

    /// Dynamic-range truncation of one operand (see [`reduce_k`]).
    #[inline]
    fn reduce(&self, v: u32) -> (u32, u32) {
        reduce_k(v, self.k)
    }
}

impl Multiplier for Drum {
    fn name(&self) -> String {
        format!("drum{}", self.k)
    }

    fn mul(&self, a: u32, b: u32) -> u64 {
        let (ta, sa) = self.reduce(a);
        let (tb, sb) = self.reduce(b);
        (ta as u64 * tb as u64) << (sa + sb)
    }

    /// Hoisted-`k` reduction loop (scalar builds) or the explicit
    /// vector kernel (`simd` feature) — bit-identical to `mul` either
    /// way (`tests/mult_batch.rs`, `tests/simd_parity.rs`).
    fn mul_batch(&self, a: &[u32], b: &[u32], out: &mut [u64]) {
        check_batch_lens(a, b, out);
        #[cfg(feature = "simd")]
        super::simd::drum_mul_batch(self.k, a, b, out);
        #[cfg(not(feature = "simd"))]
        {
            let k = self.k;
            for ((&x, &y), o) in a.iter().zip(b).zip(out.iter_mut()) {
                let (ta, sa) = reduce_k(x, k);
                let (tb, sb) = reduce_k(y, k);
                *o = (ta as u64 * tb as u64) << (sa + sb);
            }
        }
    }

    #[cfg(feature = "simd")]
    fn simd_kernel(&self) -> Option<super::simd::UnsignedKernel<'_>> {
        Some(super::simd::UnsignedKernel::Drum { k: self.k })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mult::{characterize, OperandDist};

    #[test]
    fn small_operands_exact() {
        let d = Drum::new(6).unwrap();
        for a in 0..64u32 {
            for b in 0..64u32 {
                assert_eq!(d.mul(a, b), a as u64 * b as u64, "{a}*{b}");
            }
        }
    }

    #[test]
    fn reduce_keeps_k_bits() {
        let d = Drum::new(6).unwrap();
        let (t, s) = d.reduce(0xFFFF_FFFF);
        assert!(t < 64);
        assert_eq!(s, 26);
        assert_eq!(t, 0b111111);
    }

    #[test]
    fn drum6_published_error_stats() {
        // DRUM-6 on full-range uniform operands: MRE ~1.47%, near-zero
        // mean (the ICCAD'15 numbers the paper quotes).
        let d = Drum::new(6).unwrap();
        let stats = characterize(&d, OperandDist::Uniform16, 200_000, 7);
        assert!(
            (0.010..0.020).contains(&stats.mre),
            "DRUM-6 MRE {:.4} outside published band",
            stats.mre
        );
        assert!(stats.mean_re.abs() < 0.004, "bias {:.4}", stats.mean_re);
    }

    #[test]
    fn larger_k_is_more_accurate() {
        let mre = |k| {
            characterize(&Drum::new(k).unwrap(), OperandDist::Uniform16, 50_000, 3).mre
        };
        assert!(mre(4) > mre(6));
        assert!(mre(6) > mre(8));
    }

    #[test]
    fn never_panics_on_extremes() {
        let d = Drum::new(3).unwrap();
        for &v in &[0u32, 1, 2, u32::MAX, 1 << 31] {
            let _ = d.mul(v, v);
        }
    }
}
