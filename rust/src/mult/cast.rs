//! Audited float→int crossings for the multiplier subsystem.
//!
//! Bare float→int `as` casts saturate and truncate silently, and a
//! mis-rounded crossing in a bit-decomposition path corrupts products
//! without any error surfacing. detlint rule S1 therefore bans them in
//! `mult/`; the helpers here are the single reviewed crossing, each one
//! stating its domain and clamping behaviour.

/// Clamp `v` into the representable product range `[0, u64::MAX]` and
/// truncate toward zero, exactly as a real unsigned hardware multiplier
/// bounds its output. NaN maps to 0 (`max(0.0)` on NaN yields 0.0).
///
/// The clamped `as` cast below is bit-for-bit the expression the
/// Gaussian model has always used, so trajectories are unchanged.
#[inline]
pub fn sat_f64_to_u64(v: f64) -> u64 {
    // detlint: allow(S1) -- this helper IS the audited crossing: input clamped to [0, u64::MAX], NaN -> 0
    v.max(0.0).min(u64::MAX as f64) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn saturates_and_truncates() {
        assert_eq!(sat_f64_to_u64(0.0), 0);
        assert_eq!(sat_f64_to_u64(-1.5), 0);
        assert_eq!(sat_f64_to_u64(f64::NEG_INFINITY), 0);
        assert_eq!(sat_f64_to_u64(f64::NAN), 0);
        assert_eq!(sat_f64_to_u64(41.999), 41);
        assert_eq!(sat_f64_to_u64(f64::INFINITY), u64::MAX);
        // u64::MAX as f64 rounds up to 2^64, which `as` saturates back.
        assert_eq!(sat_f64_to_u64(u64::MAX as f64), u64::MAX);
        assert_eq!(sat_f64_to_u64(1e300), u64::MAX);
        // Exactly representable large value round-trips.
        assert_eq!(sat_f64_to_u64((1u64 << 53) as f64), 1u64 << 53);
    }
}
