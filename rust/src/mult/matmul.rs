//! Host-side bit-accurate approximate GEMM.
//!
//! The characterization harness samples random operand *pairs*; real
//! training error emerges from operand pairs inside dot-product chains.
//! [`approx_matmul`] closes that gap: every scalar product in
//! `C = A·B` is computed by decomposing the f32 operands into sign /
//! exponent / 24-bit mantissa, running the mantissa product through a
//! bit-accurate [`Multiplier`], renormalizing back to f32 (truncating
//! ties like the hardware designs do), and accumulating in f32 in
//! strict k-order — i.e. exactly what an approximate FP MAC array would
//! produce. ApproxTrain (arXiv:2209.04161) calls the same construction
//! `AMDNN`'s simulated GEMM.
//!
//! ## How the prepared GEMM works
//!
//! The kernel is built around [`PreparedMatrix`] (see
//! [`super::prepared`]): each operand is decomposed **once per GEMM** —
//! A into row-major `[rows × inner]` planes, B packed into
//! column-major `[cols × inner]` panels — so the k-chain of every
//! output element streams two contiguous plane slices instead of
//! re-decomposing `rows × cols` times. On top of that the kernel is
//! cache-blocked: output rows are split into [`gemm_row_block`]-row
//! tasks (a pure function of the shape — input-derived, so results
//! are identical at any worker count) and the j-loop walks
//! [`GEMM_COL_BLOCK`]-column panels, reusing the A-row planes from L1
//! and the packed B panel from L2 across the block. Each output
//! element's mantissa products still go through one
//! [`Multiplier::mul_batch`] call per k-chain (the monomorphized fast
//! path), and the chain is reassembled **in k-order**: batch products
//! and non-finite fallback terms are merged by their k index, so the
//! f32 accumulation is bit-identical to a scalar [`approx_mul_f32`]
//! walk of the same chain ([`approx_matmul_reference`] is that walk,
//! kept as the property-test oracle). Under the `simd` cargo feature,
//! designs that expose a kernel descriptor
//! ([`Multiplier::simd_kernel`]) swap the per-element engine for the
//! vector chain microkernel ([`super::simd`]) — class test, mantissa
//! products and renormalization lane-parallel, final accumulation
//! still strict k-order scalar, outputs still bit-identical.
//!
//! Callers with an epilogue (the native backend's bias-add and
//! batch-norm statistics) use [`approx_matmul_prepared`] directly: the
//! bias add and the per-channel output sums are fused into the output
//! block loop instead of running as separate full-tensor passes. The
//! per-channel sums are accumulated per row-block and merged in block
//! order — deterministic and thread-count independent, because the
//! block size is a pure function of the shape.
//!
//! Non-finite inputs fall back to the native f32 product; zeros and
//! subnormals flush to (signed) zero, as the integer designs have no
//! subnormal path. A flushed term contributes a signed zero to the
//! chain, which f32 accumulation cannot distinguish from skipping it
//! (the accumulator can never be `-0.0` mid-chain), so the kernel
//! skips them.

use anyhow::{bail, Result};

use crate::parallel;
use crate::rng::Xoshiro256;

use super::prepared::{element_value, EXP_NONFINITE};
use super::stats::Welford;
use super::{ErrorStats, Exact, Multiplier, PreparedMatrix};

/// Upper bound on rows per parallel task of the blocked kernel.
pub const GEMM_ROW_BLOCK: usize = 64;

/// Row blocks a GEMM is split into when it has at least that many
/// rows, so small-row GEMMs (dense layers: rows = batch) still
/// parallelize instead of collapsing into one task.
const GEMM_ROW_SPLIT: usize = 16;

/// Columns per B-panel of the blocked kernel's j-loop (shared with
/// the signed kernel in [`super::signed::matmul`]).
pub(super) const GEMM_COL_BLOCK: usize = 48;

/// Rows per parallel task for a `rows`-row GEMM — a pure function of
/// the row count, **never** the worker count, so the per-block
/// epilogue partials (and therefore whole training trajectories) are
/// bit-identical at any thread count.
pub fn gemm_row_block(rows: usize) -> usize {
    rows.div_ceil(GEMM_ROW_SPLIT).clamp(1, GEMM_ROW_BLOCK)
}

/// Decompose a finite f32 into `(sign, biased exponent, 24-bit
/// mantissa)`; `None` for zero/subnormal (flushed).
#[inline]
pub(super) fn decompose(x: f32) -> Option<(u32, i32, u32)> {
    let bits = x.to_bits();
    let exp = ((bits >> 23) & 0xFF) as i32;
    if exp == 0 {
        return None;
    }
    Some((bits >> 31, exp, (bits & 0x007F_FFFF) | 0x0080_0000))
}

/// Renormalize an approximate 24×24-bit mantissa product back to f32.
/// `ex`/`ey` are the operands' biased exponents; truncates the mantissa
/// (no round-to-nearest — matching the truncating hardware designs),
/// saturates to ±inf on overflow and flushes to signed zero on
/// underflow.
#[inline]
pub(super) fn renorm(sign: u32, ex: i32, ey: i32, p: u64) -> f32 {
    if p == 0 {
        return f32::from_bits(sign << 31);
    }
    let q = 63 - p.leading_zeros() as i32;
    let mant = if q > 23 {
        (p >> (q - 23)) as u32
    } else {
        (p as u32) << (23 - q)
    };
    // x*y = mx*my * 2^(ex+ey-300); float(mant, er) = mant * 2^(er-150).
    let er = ex + ey + q - 173;
    if er >= 255 {
        return f32::from_bits((sign << 31) | 0x7F80_0000);
    }
    if er <= 0 {
        return f32::from_bits(sign << 31);
    }
    f32::from_bits((sign << 31) | ((er as u32) << 23) | (mant & 0x007F_FFFF))
}

/// One bit-accurate approximate f32 product: `m` multiplies the
/// mantissas, the exponent add is exact.
pub fn approx_mul_f32(m: &dyn Multiplier, x: f32, y: f32) -> f32 {
    if !x.is_finite() || !y.is_finite() {
        return x * y;
    }
    match (decompose(x), decompose(y)) {
        (Some((sx, ex, mx)), Some((sy, ey, my))) => {
            renorm(sx ^ sy, ex, ey, m.mul(mx, my))
        }
        _ => f32::from_bits((x.to_bits() ^ y.to_bits()) & 0x8000_0000),
    }
}

/// Per-task staging for the scalar-batch chain engine: compacted
/// mantissa pairs, their products, the (sign, exponent-sum, k index)
/// of each batched term, and the non-finite fallback terms.
struct ChainBufs {
    ma: Vec<u32>,
    mb: Vec<u32>,
    prod: Vec<u64>,
    sgn: Vec<u32>,
    esum: Vec<i32>,
    slot: Vec<u32>,
    extra_k: Vec<u32>,
    extra_v: Vec<f32>,
}

impl ChainBufs {
    fn new(inner: usize) -> Self {
        ChainBufs {
            ma: vec![0u32; inner],
            mb: vec![0u32; inner],
            prod: vec![0u64; inner],
            sgn: vec![0u32; inner],
            esum: vec![0i32; inner],
            slot: vec![0u32; inner],
            extra_k: Vec::new(),
            extra_v: Vec::new(),
        }
    }
}

/// One output element's k-chain through the scalar-batch engine:
/// compact the both-normal operand pairs, one [`Multiplier::mul_batch`]
/// call over them, then a strict k-order merge of batched and
/// non-finite fallback terms. Under the `simd` feature, designs with a
/// kernel descriptor take [`super::simd::unsigned_chain_sum`] instead;
/// both engines produce bit-identical sums.
fn chain_sum(
    m: &dyn Multiplier,
    a_row: (&[u8], &[i32], &[u32]),
    b_row: (&[u8], &[i32], &[u32]),
    bufs: &mut ChainBufs,
) -> f32 {
    let (sa, ea, mta) = a_row;
    let (sb, eb, mtb) = b_row;
    let inner = ea.len();
    let mut active = 0usize;
    bufs.extra_k.clear();
    bufs.extra_v.clear();
    for k in 0..inner {
        let (ex, ey) = (ea[k], eb[k]);
        if ex > 0 && ex != EXP_NONFINITE && ey > 0 && ey != EXP_NONFINITE {
            // Both operands normal: batch the mantissa product.
            bufs.ma[active] = mta[k];
            bufs.mb[active] = mtb[k];
            bufs.sgn[active] = (sa[k] ^ sb[k]) as u32;
            bufs.esum[active] = ex + ey;
            bufs.slot[active] = k as u32;
            active += 1;
        } else if ex == EXP_NONFINITE || ey == EXP_NONFINITE {
            // Native product fallback, replayed at its k position in
            // the merge below.
            let x = element_value(sa[k], ex, mta[k]);
            let y = element_value(sb[k], ey, mtb[k]);
            bufs.extra_k.push(k as u32);
            bufs.extra_v.push(x * y);
        }
        // Flushed terms contribute a signed zero — a no-op in the
        // k-order accumulation.
    }
    m.mul_batch(&bufs.ma[..active], &bufs.mb[..active], &mut bufs.prod[..active]);
    // Reassemble the chain in strict k-order: both term lists are
    // k-sorted, so merge them.
    let mut acc = 0f32;
    let (mut t, mut e) = (0usize, 0usize);
    while t < active || e < bufs.extra_k.len() {
        let kt = if t < active { bufs.slot[t] } else { u32::MAX };
        let ke = if e < bufs.extra_k.len() { bufs.extra_k[e] } else { u32::MAX };
        if kt < ke {
            acc += renorm(bufs.sgn[t], bufs.esum[t], 0, bufs.prod[t]);
            t += 1;
        } else {
            acc += bufs.extra_v[e];
            e += 1;
        }
    }
    acc
}

/// Output of [`approx_matmul_prepared`].
pub struct GemmOutput {
    /// Row-major `[rows × cols]` product (bias already added when a
    /// bias was fused).
    pub out: Vec<f32>,
    /// Per-column sums of the (biased) output, when requested — the
    /// batch-norm mean epilogue, accumulated per row-block and merged
    /// in block order.
    pub col_sums: Option<Vec<f32>>,
}

/// The blocked decompose-once kernel: `C = A·B` over prepared planes,
/// with optional fused epilogues.
///
/// * `a` — the left operand, `[rows × inner]` planes;
/// * `b_packed` — the right operand packed column-major: plane row `j`
///   holds B's column `j` as a contiguous length-`inner` panel;
/// * `bias` — fused per-column bias add (`out[i,j] = acc + bias[j]`);
/// * `with_col_sums` — fused per-column sums of the biased output.
///
/// Every output element is bit-identical to a scalar
/// [`approx_mul_f32`] walk of its k-chain plus the bias add (pinned by
/// [`approx_matmul_reference`] property tests), parallel over fixed
/// row blocks, deterministic at any worker count.
pub fn approx_matmul_prepared(
    m: &dyn Multiplier,
    a: &PreparedMatrix,
    b_packed: &PreparedMatrix,
    bias: Option<&[f32]>,
    with_col_sums: bool,
) -> Result<GemmOutput> {
    let rows = a.rows();
    let inner = a.cols();
    let cols = b_packed.rows();
    if b_packed.cols() != inner {
        bail!(
            "approx_matmul_prepared: A is [{rows}x{inner}] but packed B \
             holds length-{} panels",
            b_packed.cols()
        );
    }
    if let Some(b) = bias {
        if b.len() != cols {
            bail!(
                "approx_matmul_prepared: bias has {} entries for {cols} columns",
                b.len()
            );
        }
    }
    if rows == 0 || cols == 0 {
        return Ok(GemmOutput {
            out: vec![0f32; rows * cols],
            col_sums: with_col_sums.then(|| vec![0f32; cols]),
        });
    }

    let threads = parallel::max_threads();
    let block = gemm_row_block(rows);
    // The kernel descriptor is `Copy` and resolved once per GEMM; the
    // dispatch inside the task closure is branch-predicted away.
    #[cfg(feature = "simd")]
    let kernel = m.simd_kernel();
    let mut out = vec![0f32; rows * cols];
    let partials: Vec<Option<Vec<f32>>> =
        parallel::par_chunks_mut(&mut out, block * cols, threads, |bi, chunk| {
            let mut bufs = ChainBufs::new(inner);
            // Per-task term-bit scratch for the SIMD chain engine.
            #[cfg(feature = "simd")]
            let mut terms = vec![0u32; inner];
            let mut sums = with_col_sums.then(|| vec![0f32; cols]);

            let r0 = bi * block;
            let block_rows = chunk.len() / cols;
            // Panel loop outermost: the [`GEMM_COL_BLOCK`]-column B
            // panel stays cache-resident across every row of the
            // block; the A-row planes are cheap re-slices.
            let mut j0 = 0usize;
            while j0 < cols {
                let j1 = (j0 + GEMM_COL_BLOCK).min(cols);
                for ri in 0..block_rows {
                    let a_row = a.row(r0 + ri);
                    for j in j0..j1 {
                        let b_row = b_packed.row(j);
                        #[cfg(feature = "simd")]
                        let acc = match kernel {
                            Some(uk) => super::simd::unsigned_chain_sum(
                                uk, a_row, b_row, &mut terms,
                            ),
                            None => chain_sum(m, a_row, b_row, &mut bufs),
                        };
                        #[cfg(not(feature = "simd"))]
                        let acc = chain_sum(m, a_row, b_row, &mut bufs);
                        let v = match bias {
                            Some(b) => acc + b[j],
                            None => acc,
                        };
                        chunk[ri * cols + j] = v;
                        if let Some(s) = sums.as_mut() {
                            s[j] += v;
                        }
                    }
                }
                j0 = j1;
            }
            sums
        });

    let col_sums = if with_col_sums {
        let mut total = vec![0f32; cols];
        for p in partials.into_iter().flatten() {
            for (t, v) in total.iter_mut().zip(&p) {
                *t += *v;
            }
        }
        Some(total)
    } else {
        None
    };
    Ok(GemmOutput { out, col_sums })
}

/// `C[rows×cols] = A[rows×inner] · B[inner×cols]` (row-major slices)
/// with every scalar product computed bit-accurately by `m` and f32
/// accumulation in k-order. Operands are prepared once (see the module
/// docs), the kernel is blocked and parallel over input-derived row
/// blocks — deterministic at any worker count.
pub fn approx_matmul(
    m: &dyn Multiplier,
    a: &[f32],
    b: &[f32],
    rows: usize,
    inner: usize,
    cols: usize,
) -> Result<Vec<f32>> {
    if a.len() != rows * inner || b.len() != inner * cols {
        bail!(
            "approx_matmul: ({rows}x{inner})·({inner}x{cols}) needs {} and {} \
             elements, got {} and {}",
            rows * inner,
            inner * cols,
            a.len(),
            b.len()
        );
    }
    let ap = PreparedMatrix::prepare_strided(a, rows, inner, inner, 1)?;
    let bp = PreparedMatrix::prepare_strided(b, cols, inner, 1, cols)?;
    Ok(approx_matmul_prepared(m, &ap, &bp, None, false)?.out)
}

/// `C[rows×cols] = Aᵀ · B` where `a` is the **untransposed**
/// `[inner×rows]` row-major matrix. The backward pass's `dW = Xᵀ·dY`
/// runs through this, so weight gradients see the same bit-accurate
/// multiplier as the forward GEMM without materializing a transpose.
/// Bit-identical to transposing `a` and calling [`approx_matmul`]
/// (pinned by tests): the error of each scalar product depends only on
/// the operand values, and accumulation stays in k-order.
pub fn approx_matmul_tn(
    m: &dyn Multiplier,
    a: &[f32],
    b: &[f32],
    rows: usize,
    inner: usize,
    cols: usize,
) -> Result<Vec<f32>> {
    if a.len() != inner * rows || b.len() != inner * cols {
        bail!(
            "approx_matmul_tn: ({inner}x{rows})ᵀ·({inner}x{cols}) needs {} and {} \
             elements, got {} and {}",
            inner * rows,
            inner * cols,
            a.len(),
            b.len()
        );
    }
    let ap = PreparedMatrix::prepare_strided(a, rows, inner, 1, rows)?;
    let bp = PreparedMatrix::prepare_strided(b, cols, inner, 1, cols)?;
    Ok(approx_matmul_prepared(m, &ap, &bp, None, false)?.out)
}

/// `C[rows×cols] = A · Bᵀ` where `b` is the **untransposed**
/// `[cols×inner]` row-major matrix — the backward pass's `dX = dY·Wᵀ`.
/// Same determinism/identity contract as [`approx_matmul_tn`].
pub fn approx_matmul_nt(
    m: &dyn Multiplier,
    a: &[f32],
    b: &[f32],
    rows: usize,
    inner: usize,
    cols: usize,
) -> Result<Vec<f32>> {
    if a.len() != rows * inner || b.len() != cols * inner {
        bail!(
            "approx_matmul_nt: ({rows}x{inner})·({cols}x{inner})ᵀ needs {} and {} \
             elements, got {} and {}",
            rows * inner,
            cols * inner,
            a.len(),
            b.len()
        );
    }
    let ap = PreparedMatrix::prepare_strided(a, rows, inner, inner, 1)?;
    let bp = PreparedMatrix::prepare_strided(b, cols, inner, inner, 1)?;
    Ok(approx_matmul_prepared(m, &ap, &bp, None, false)?.out)
}

/// The scalar reference kernel: `acc += approx_mul_f32(m, A[i,k],
/// B[k,j])` in strict k-order, one virtual call per product, no
/// batching, no blocking, no parallelism. Slow by construction — it
/// exists as the bit-identity oracle for the blocked prepared kernel
/// (`tests/prepared_gemm.rs` pins `approx_matmul` ≡ this for every
/// design × operand layout × thread count).
pub fn approx_matmul_reference(
    m: &dyn Multiplier,
    a: &[f32],
    b: &[f32],
    rows: usize,
    inner: usize,
    cols: usize,
) -> Result<Vec<f32>> {
    if a.len() != rows * inner || b.len() != inner * cols {
        bail!(
            "approx_matmul_reference: ({rows}x{inner})·({inner}x{cols}) needs \
             {} and {} elements, got {} and {}",
            rows * inner,
            inner * cols,
            a.len(),
            b.len()
        );
    }
    let mut out = vec![0f32; rows * cols];
    for i in 0..rows {
        for j in 0..cols {
            let mut acc = 0f32;
            for k in 0..inner {
                acc += approx_mul_f32(m, a[i * inner + k], b[k * cols + j]);
            }
            out[i * cols + j] = acc;
        }
    }
    Ok(out)
}

/// Seeded random operand matrices (uniform in `[-1, 1)`) for GEMM
/// characterization.
pub(super) fn seeded_matrices(
    rows: usize,
    inner: usize,
    cols: usize,
    seed: u64,
) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Xoshiro256::new(seed);
    let a = (0..rows * inner).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
    let b = (0..inner * cols).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
    (a, b)
}

/// Relative-error statistics of `approx` GEMM output vs the exact
/// pipeline's output (0 error where the reference is 0).
pub(super) fn output_error_stats(approx: &[f32], exact: &[f32]) -> ErrorStats {
    let mut acc = Welford::new();
    for (&ap, &ex) in approx.iter().zip(exact) {
        let re = if ex == 0.0 {
            0.0
        } else {
            (ap as f64 - ex as f64) / ex as f64
        };
        acc.push(re);
    }
    acc.finish()
}

/// Model-vs-bit-accurate comparison on a real GEMM shape: run `m` and
/// [`Exact`] through the same mantissa pipeline on seeded random
/// matrices (uniform in `[-1, 1)`), and return error statistics of the
/// relative output error over all `rows*cols` elements.
pub fn characterize_matmul(
    m: &dyn Multiplier,
    rows: usize,
    inner: usize,
    cols: usize,
    seed: u64,
) -> Result<ErrorStats> {
    if rows == 0 || inner == 0 || cols == 0 {
        bail!("characterize_matmul: empty shape {rows}x{inner}x{cols}");
    }
    let (a, b) = seeded_matrices(rows, inner, cols, seed);
    let approx = approx_matmul(m, &a, &b, rows, inner, cols)?;
    let exact = approx_matmul(&Exact, &a, &b, rows, inner, cols)?;
    Ok(output_error_stats(&approx, &exact))
}

/// [`characterize_matmul`] over a design set: the operand matrices and
/// the exact-reference GEMM are computed once and shared, instead of
/// once per design. Returns stats in design order.
pub fn characterize_matmul_set(
    designs: &[Box<dyn Multiplier>],
    rows: usize,
    inner: usize,
    cols: usize,
    seed: u64,
) -> Result<Vec<ErrorStats>> {
    if rows == 0 || inner == 0 || cols == 0 {
        bail!("characterize_matmul: empty shape {rows}x{inner}x{cols}");
    }
    let (a, b) = seeded_matrices(rows, inner, cols, seed);
    let exact = approx_matmul(&Exact, &a, &b, rows, inner, cols)?;
    designs
        .iter()
        .map(|d| {
            let approx = approx_matmul(d.as_ref(), &a, &b, rows, inner, cols)?;
            Ok(output_error_stats(&approx, &exact))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mult::{Drum, Mitchell};

    /// f64 reference through the same flush/truncate conventions is
    /// overkill here; instead compare the Exact pipeline against the
    /// native product, which it must match within 1 ulp (truncation vs
    /// round-to-nearest).
    #[test]
    fn exact_pipeline_within_one_ulp_of_native() {
        let mut rng = Xoshiro256::new(17);
        for _ in 0..50_000 {
            let x = f32::from_bits(rng.next_u32());
            let y = f32::from_bits(rng.next_u32());
            if !x.is_normal() || !y.is_normal() {
                continue;
            }
            let native = x * y;
            if !native.is_normal() {
                continue; // overflow/underflow edge conventions differ
            }
            let ours = approx_mul_f32(&Exact, x, y);
            let diff = (ours.to_bits() as i64 - native.to_bits() as i64).abs();
            assert!(diff <= 1, "{x} * {y}: {ours} vs {native} ({diff} ulp)");
        }
    }

    #[test]
    fn powers_of_two_are_exact() {
        for i in -8i32..8 {
            for j in -8i32..8 {
                let (x, y) = (2f32.powi(i), 2f32.powi(j));
                assert_eq!(approx_mul_f32(&Exact, x, y), x * y, "{x}*{y}");
                assert_eq!(approx_mul_f32(&Mitchell, x, y), x * y, "{x}*{y}");
            }
        }
    }

    #[test]
    fn signs_and_zeros() {
        assert_eq!(approx_mul_f32(&Exact, -2.0, 3.0), -6.0);
        assert_eq!(approx_mul_f32(&Exact, -2.0, -3.0), 6.0);
        assert_eq!(approx_mul_f32(&Exact, 0.0, 5.0), 0.0);
        assert!(approx_mul_f32(&Exact, -0.0, 5.0).to_bits() == 0x8000_0000);
        assert!(approx_mul_f32(&Exact, f32::NAN, 5.0).is_nan());
    }

    #[test]
    fn matmul_exact_matches_f64_reference() {
        let (rows, inner, cols) = (7, 13, 5);
        let mut rng = Xoshiro256::new(3);
        let a: Vec<f32> = (0..rows * inner).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
        let b: Vec<f32> = (0..inner * cols).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
        let c = approx_matmul(&Exact, &a, &b, rows, inner, cols).unwrap();
        for i in 0..rows {
            for j in 0..cols {
                let mut want = 0f64;
                for k in 0..inner {
                    want += a[i * inner + k] as f64 * b[k * cols + j] as f64;
                }
                let got = c[i * cols + j] as f64;
                // f32 accumulation + per-product truncation: loose bound.
                assert!(
                    (got - want).abs() < 1e-4 * (1.0 + want.abs()),
                    "c[{i}][{j}] = {got}, want {want}"
                );
            }
        }
    }

    #[test]
    fn blocked_kernel_matches_scalar_reference() {
        // Shapes spanning multiple row blocks and column panels, so the
        // blocking/merge paths are all exercised.
        let d = Drum::new(6).unwrap();
        let mut rng = Xoshiro256::new(23);
        let (rows, inner, cols) = (2 * GEMM_ROW_BLOCK + 7, 19, GEMM_COL_BLOCK + 5);
        let a: Vec<f32> = (0..rows * inner).map(|_| rng.next_f32() - 0.5).collect();
        let b: Vec<f32> = (0..inner * cols).map(|_| rng.next_f32() - 0.5).collect();
        let fast = approx_matmul(&d, &a, &b, rows, inner, cols).unwrap();
        let slow = approx_matmul_reference(&d, &a, &b, rows, inner, cols).unwrap();
        assert_eq!(fast, slow);
    }

    #[test]
    fn nonfinite_midchain_accumulates_in_k_order() {
        // Two finite products whose running sum overflows to +inf,
        // then a -inf term: true k-order gives (+inf) + (-inf) = NaN.
        // The old kernel accumulated non-finite terms *before* the
        // batched finite products and returned -inf here.
        let big = 1.8e19f32; // big*big ≈ 3.24e38, finite
        let a = [big, big, f32::NEG_INFINITY];
        let b = [big, big, 1.0f32];
        let c = approx_matmul(&Exact, &a, &b, 1, 3, 1).unwrap();
        assert!(c[0].is_nan(), "k-order violated: got {}", c[0]);
        let r = approx_matmul_reference(&Exact, &a, &b, 1, 3, 1).unwrap();
        assert!(r[0].is_nan());

        // NaN and inf planted mid-chain among normals, zeros and
        // subnormals: blocked kernel ≡ scalar walk, bitwise.
        let d = Drum::new(6).unwrap();
        let mut rng = Xoshiro256::new(29);
        let (rows, inner, cols) = (5usize, 11usize, 4usize);
        let mut a: Vec<f32> = (0..rows * inner).map(|_| rng.next_f32() - 0.5).collect();
        let mut b: Vec<f32> = (0..inner * cols).map(|_| rng.next_f32() - 0.5).collect();
        a[3] = f32::INFINITY;
        a[17] = 0.0;
        a[25] = f32::NAN;
        b[5] = f32::NEG_INFINITY;
        b[9] = -0.0;
        b[20] = 1.0e-41; // subnormal -> flushed
        let fast = approx_matmul(&d, &a, &b, rows, inner, cols).unwrap();
        let slow = approx_matmul_reference(&d, &a, &b, rows, inner, cols).unwrap();
        assert_eq!(fast.len(), slow.len());
        for (f, s) in fast.iter().zip(&slow) {
            assert_eq!(f.to_bits(), s.to_bits(), "{f} vs {s}");
        }
    }

    #[test]
    fn fused_bias_and_col_sums_match_unfused() {
        let d = Drum::new(6).unwrap();
        let mut rng = Xoshiro256::new(31);
        let (rows, inner, cols) = (GEMM_ROW_BLOCK + 9, 13usize, 6usize);
        let a: Vec<f32> = (0..rows * inner).map(|_| rng.next_f32() - 0.5).collect();
        let b: Vec<f32> = (0..inner * cols).map(|_| rng.next_f32() - 0.5).collect();
        let bias: Vec<f32> = (0..cols).map(|_| rng.next_f32() - 0.5).collect();
        let ap = PreparedMatrix::prepare(&a, rows, inner).unwrap();
        let bp = PreparedMatrix::prepare_strided(&b, cols, inner, 1, cols).unwrap();
        let fused =
            approx_matmul_prepared(&d, &ap, &bp, Some(&bias), true).unwrap();
        let mut plain = approx_matmul(&d, &a, &b, rows, inner, cols).unwrap();
        for r in 0..rows {
            for c in 0..cols {
                plain[r * cols + c] += bias[c];
            }
        }
        assert_eq!(fused.out, plain);
        // Column sums: per input-derived row block, merged in block order.
        let sums = fused.col_sums.unwrap();
        let mut want = vec![0f32; cols];
        for blk in plain.chunks(gemm_row_block(rows) * cols) {
            let mut part = vec![0f32; cols];
            for row in blk.chunks(cols) {
                for (p, &v) in part.iter_mut().zip(row) {
                    *p += v;
                }
            }
            for (w, p) in want.iter_mut().zip(&part) {
                *w += p;
            }
        }
        assert_eq!(sums, want);
    }

    #[test]
    fn matmul_is_deterministic_across_calls() {
        let d = Drum::new(6).unwrap();
        let mut rng = Xoshiro256::new(8);
        let a: Vec<f32> = (0..32 * 24).map(|_| rng.next_f32() - 0.5).collect();
        let b: Vec<f32> = (0..24 * 16).map(|_| rng.next_f32() - 0.5).collect();
        let c1 = approx_matmul(&d, &a, &b, 32, 24, 16).unwrap();
        let c2 = approx_matmul(&d, &a, &b, 32, 24, 16).unwrap();
        assert_eq!(c1, c2);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(approx_matmul(&Exact, &[0.0; 5], &[0.0; 6], 2, 3, 2).is_err());
        assert!(approx_matmul_reference(&Exact, &[0.0; 5], &[0.0; 6], 2, 3, 2).is_err());
        assert!(characterize_matmul(&Exact, 0, 3, 2, 1).is_err());
        assert!(characterize_matmul_set(&[], 2, 0, 2, 1).is_err());
    }

    #[test]
    fn matmul_set_matches_individual_runs() {
        let designs: Vec<Box<dyn Multiplier>> =
            vec![Box::new(Exact), Box::new(Drum::new(6).unwrap()), Box::new(Mitchell)];
        let set = characterize_matmul_set(&designs, 8, 16, 8, 3).unwrap();
        assert_eq!(set.len(), designs.len());
        for (d, s) in designs.iter().zip(&set) {
            let solo = characterize_matmul(d.as_ref(), 8, 16, 8, 3).unwrap();
            assert_eq!(s.mre, solo.mre, "{}", d.name());
            assert_eq!(s.sd, solo.sd, "{}", d.name());
        }
    }

    #[test]
    fn gemm_error_tracks_design_error() {
        // DRUM-6's per-product error is ~1.5%; after accumulation over
        // k=32 chains the *output* relative error stays the same order.
        let d = Drum::new(6).unwrap();
        let s = characterize_matmul(&d, 16, 32, 16, 5).unwrap();
        assert_eq!(s.samples, 256);
        assert!(s.mre > 1e-4, "mre {}", s.mre);
        // Upper band is loose: near-zero outputs of a random GEMM
        // legitimately inflate relative error.
        assert!(s.mre < 0.25, "mre {}", s.mre);
        // Exact through the same pipeline: zero error by construction.
        let e = characterize_matmul(&Exact, 16, 32, 16, 5).unwrap();
        assert_eq!(e.mre, 0.0);
    }

    fn transpose(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
        let mut out = vec![0f32; src.len()];
        for r in 0..rows {
            for c in 0..cols {
                out[c * rows + r] = src[r * cols + c];
            }
        }
        out
    }

    #[test]
    fn tn_matches_explicit_transpose_bitwise() {
        // C = Aᵀ·B must be bit-identical to transposing A and running
        // the NN kernel — same products, same accumulation order.
        let (rows, inner, cols) = (9, 14, 6);
        let d = Drum::new(6).unwrap();
        let mut rng = Xoshiro256::new(41);
        // a stored untransposed: [inner x rows]
        let a: Vec<f32> = (0..inner * rows).map(|_| rng.next_f32() - 0.5).collect();
        let b: Vec<f32> = (0..inner * cols).map(|_| rng.next_f32() - 0.5).collect();
        let tn = approx_matmul_tn(&d, &a, &b, rows, inner, cols).unwrap();
        let at = transpose(&a, inner, rows); // [rows x inner]
        let nn = approx_matmul(&d, &at, &b, rows, inner, cols).unwrap();
        assert_eq!(tn, nn);
    }

    #[test]
    fn nt_matches_explicit_transpose_bitwise() {
        let (rows, inner, cols) = (7, 11, 8);
        let d = Mitchell;
        let mut rng = Xoshiro256::new(42);
        let a: Vec<f32> = (0..rows * inner).map(|_| rng.next_f32() - 0.5).collect();
        // b stored untransposed: [cols x inner]
        let b: Vec<f32> = (0..cols * inner).map(|_| rng.next_f32() - 0.5).collect();
        let nt = approx_matmul_nt(&d, &a, &b, rows, inner, cols).unwrap();
        let bt = transpose(&b, cols, inner); // [inner x cols]
        let nn = approx_matmul(&d, &a, &bt, rows, inner, cols).unwrap();
        assert_eq!(nt, nn);
    }

    #[test]
    fn transposed_variants_deterministic_across_calls() {
        // Thread-count independence is pinned end to end by
        // tests/prepared_gemm.rs; here: repeat-call identity.
        let d = Drum::new(6).unwrap();
        let mut rng = Xoshiro256::new(43);
        let a: Vec<f32> = (0..24 * 16).map(|_| rng.next_f32() - 0.5).collect();
        let b: Vec<f32> = (0..24 * 12).map(|_| rng.next_f32() - 0.5).collect();
        assert_eq!(
            approx_matmul_tn(&d, &a, &b, 16, 24, 12).unwrap(),
            approx_matmul_tn(&d, &a, &b, 16, 24, 12).unwrap()
        );
        assert_eq!(
            approx_matmul_nt(&d, &b, &a, 12, 24, 16).unwrap(),
            approx_matmul_nt(&d, &b, &a, 12, 24, 16).unwrap()
        );
    }

    #[test]
    fn transposed_variants_reject_bad_shapes() {
        assert!(approx_matmul_tn(&Exact, &[0.0; 5], &[0.0; 6], 2, 3, 2).is_err());
        assert!(approx_matmul_nt(&Exact, &[0.0; 5], &[0.0; 6], 2, 3, 2).is_err());
    }

    #[test]
    fn mitchell_gemm_is_biased_low() {
        // Mitchell underestimates every product, so dot products of
        // same-sign data are biased low — visible at GEMM level.
        let m = Mitchell;
        let mut rng = Xoshiro256::new(4);
        // All-positive matrices keep the bias from cancelling.
        let a: Vec<f32> = (0..8 * 64).map(|_| rng.next_f32() + 0.1).collect();
        let b: Vec<f32> = (0..64 * 8).map(|_| rng.next_f32() + 0.1).collect();
        let approx = approx_matmul(&m, &a, &b, 8, 64, 8).unwrap();
        let exact = approx_matmul(&Exact, &a, &b, 8, 64, 8).unwrap();
        let mean_re: f64 = approx
            .iter()
            .zip(&exact)
            .map(|(&ap, &ex)| (ap as f64 - ex as f64) / ex as f64)
            .sum::<f64>()
            / exact.len() as f64;
        assert!(mean_re < -0.01, "mean relative error {mean_re}");
        assert!(mean_re > -0.12, "mean relative error {mean_re}");
    }
}
