//! Host-side bit-accurate approximate GEMM.
//!
//! The characterization harness samples random operand *pairs*; real
//! training error emerges from operand pairs inside dot-product chains.
//! [`approx_matmul`] closes that gap: every scalar product in
//! `C = A·B` is computed by decomposing the f32 operands into sign /
//! exponent / 24-bit mantissa, running the mantissa product through a
//! bit-accurate [`Multiplier`] (via the batched fast path, one
//! `mul_batch` per output element's k-chain), renormalizing back to
//! f32 (truncating ties like the hardware designs do), and accumulating
//! in f32 in k-order — i.e. exactly what an approximate FP MAC array
//! would produce. ApproxTrain (arXiv:2209.04161) calls the same
//! construction `AMDNN`'s simulated GEMM.
//!
//! Parallel over output rows via [`crate::parallel::par_map`]; output
//! elements are independent, so results are deterministic at any
//! worker count.
//!
//! Non-finite inputs fall back to the native f32 product; zeros and
//! subnormals flush to (signed) zero, as the integer designs have no
//! subnormal path.

use anyhow::{bail, Result};

use crate::parallel;
use crate::rng::Xoshiro256;

use super::stats::Welford;
use super::{ErrorStats, Exact, Multiplier};

/// Decompose a finite f32 into `(sign, biased exponent, 24-bit
/// mantissa)`; `None` for zero/subnormal (flushed).
#[inline]
fn decompose(x: f32) -> Option<(u32, i32, u32)> {
    let bits = x.to_bits();
    let exp = ((bits >> 23) & 0xFF) as i32;
    if exp == 0 {
        return None;
    }
    Some((bits >> 31, exp, (bits & 0x007F_FFFF) | 0x0080_0000))
}

/// Renormalize an approximate 24×24-bit mantissa product back to f32.
/// `ex`/`ey` are the operands' biased exponents; truncates the mantissa
/// (no round-to-nearest — matching the truncating hardware designs),
/// saturates to ±inf on overflow and flushes to signed zero on
/// underflow.
#[inline]
fn renorm(sign: u32, ex: i32, ey: i32, p: u64) -> f32 {
    if p == 0 {
        return f32::from_bits(sign << 31);
    }
    let q = 63 - p.leading_zeros() as i32;
    let mant = if q > 23 {
        (p >> (q - 23)) as u32
    } else {
        (p as u32) << (23 - q)
    };
    // x*y = mx*my * 2^(ex+ey-300); float(mant, er) = mant * 2^(er-150).
    let er = ex + ey + q - 173;
    if er >= 255 {
        return f32::from_bits((sign << 31) | 0x7F80_0000);
    }
    if er <= 0 {
        return f32::from_bits(sign << 31);
    }
    f32::from_bits((sign << 31) | ((er as u32) << 23) | (mant & 0x007F_FFFF))
}

/// One bit-accurate approximate f32 product: `m` multiplies the
/// mantissas, the exponent add is exact.
pub fn approx_mul_f32(m: &dyn Multiplier, x: f32, y: f32) -> f32 {
    if !x.is_finite() || !y.is_finite() {
        return x * y;
    }
    match (decompose(x), decompose(y)) {
        (Some((sx, ex, mx)), Some((sy, ey, my))) => {
            renorm(sx ^ sy, ex, ey, m.mul(mx, my))
        }
        _ => f32::from_bits((x.to_bits() ^ y.to_bits()) & 0x8000_0000),
    }
}

/// `C[rows×cols] = A[rows×inner] · B[inner×cols]` (row-major slices)
/// with every scalar product computed bit-accurately by `m` and f32
/// accumulation in k-order. Parallel over output rows; deterministic.
pub fn approx_matmul(
    m: &dyn Multiplier,
    a: &[f32],
    b: &[f32],
    rows: usize,
    inner: usize,
    cols: usize,
) -> Result<Vec<f32>> {
    if a.len() != rows * inner || b.len() != inner * cols {
        bail!(
            "approx_matmul: ({rows}x{inner})·({inner}x{cols}) needs {} and {} \
             elements, got {} and {}",
            rows * inner,
            inner * cols,
            a.len(),
            b.len()
        );
    }
    Ok(approx_matmul_strided(m, a, b, rows, inner, cols, inner, 1, cols, 1))
}

/// `C[rows×cols] = Aᵀ · B` where `a` is the **untransposed**
/// `[inner×rows]` row-major matrix. The backward pass's `dW = Xᵀ·dY`
/// runs through this, so weight gradients see the same bit-accurate
/// multiplier as the forward GEMM without materializing a transpose.
/// Bit-identical to transposing `a` and calling [`approx_matmul`]
/// (pinned by tests): the error of each scalar product depends only on
/// the operand values, and accumulation stays in k-order.
pub fn approx_matmul_tn(
    m: &dyn Multiplier,
    a: &[f32],
    b: &[f32],
    rows: usize,
    inner: usize,
    cols: usize,
) -> Result<Vec<f32>> {
    if a.len() != inner * rows || b.len() != inner * cols {
        bail!(
            "approx_matmul_tn: ({inner}x{rows})ᵀ·({inner}x{cols}) needs {} and {} \
             elements, got {} and {}",
            inner * rows,
            inner * cols,
            a.len(),
            b.len()
        );
    }
    Ok(approx_matmul_strided(m, a, b, rows, inner, cols, 1, rows, cols, 1))
}

/// `C[rows×cols] = A · Bᵀ` where `b` is the **untransposed**
/// `[cols×inner]` row-major matrix — the backward pass's `dX = dY·Wᵀ`.
/// Same determinism/identity contract as [`approx_matmul_tn`].
pub fn approx_matmul_nt(
    m: &dyn Multiplier,
    a: &[f32],
    b: &[f32],
    rows: usize,
    inner: usize,
    cols: usize,
) -> Result<Vec<f32>> {
    if a.len() != rows * inner || b.len() != cols * inner {
        bail!(
            "approx_matmul_nt: ({rows}x{inner})·({cols}x{inner})ᵀ needs {} and {} \
             elements, got {} and {}",
            rows * inner,
            cols * inner,
            a.len(),
            b.len()
        );
    }
    Ok(approx_matmul_strided(m, a, b, rows, inner, cols, inner, 1, 1, inner))
}

/// Shared kernel behind the NN/TN/NT entry points: `A[i,k]` is read at
/// `a[i*ais + k*aks]` and `B[k,j]` at `b[k*bks + j*bjs]`, so the
/// transposed variants reuse the same staging/parallel structure with
/// different strides. Callers validate slice lengths.
#[allow(clippy::too_many_arguments)]
fn approx_matmul_strided(
    m: &dyn Multiplier,
    a: &[f32],
    b: &[f32],
    rows: usize,
    inner: usize,
    cols: usize,
    ais: usize,
    aks: usize,
    bks: usize,
    bjs: usize,
) -> Vec<f32> {
    let threads = parallel::max_threads();
    // Block rows per task (a few blocks per worker for load balance)
    // so the staging buffers are allocated once per task, not per row.
    let block = rows.div_ceil(threads.max(1) * 4).max(1);
    let blocks: Vec<(usize, usize)> = (0..rows)
        .step_by(block)
        .map(|r0| (r0, (r0 + block).min(rows)))
        .collect();
    let out_blocks = parallel::par_map(&blocks, threads, |_, &(r0, r1)| {
        // Per-task staging for one k-chain: mantissa pairs, products,
        // and the (sign, exponent-sum) metadata of the active terms.
        let mut ma = vec![0u32; inner];
        let mut mb = vec![0u32; inner];
        let mut prod = vec![0u64; inner];
        let mut sign_exp = vec![(0u32, 0i32); inner];
        let mut chunk = vec![0f32; (r1 - r0) * cols];
        for i in r0..r1 {
            for (j, slot) in chunk[(i - r0) * cols..(i - r0 + 1) * cols]
                .iter_mut()
                .enumerate()
            {
                let mut acc = 0f32;
                let mut active = 0usize;
                for k in 0..inner {
                    let x = a[i * ais + k * aks];
                    let y = b[k * bks + j * bjs];
                    if !x.is_finite() || !y.is_finite() {
                        acc += x * y;
                        continue;
                    }
                    if let (Some((sx, ex, mx)), Some((sy, ey, my))) =
                        (decompose(x), decompose(y))
                    {
                        ma[active] = mx;
                        mb[active] = my;
                        sign_exp[active] = (sx ^ sy, ex + ey);
                        active += 1;
                    }
                    // Flushed (zero/subnormal) terms contribute exactly 0.
                }
                m.mul_batch(&ma[..active], &mb[..active], &mut prod[..active]);
                for t in 0..active {
                    let (sign, exp_sum) = sign_exp[t];
                    acc += renorm(sign, exp_sum, 0, prod[t]);
                }
                *slot = acc;
            }
        }
        chunk
    });
    out_blocks.concat()
}

/// Seeded random operand matrices (uniform in `[-1, 1)`) for GEMM
/// characterization.
fn seeded_matrices(
    rows: usize,
    inner: usize,
    cols: usize,
    seed: u64,
) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Xoshiro256::new(seed);
    let a = (0..rows * inner).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
    let b = (0..inner * cols).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
    (a, b)
}

/// Relative-error statistics of `approx` GEMM output vs the exact
/// pipeline's output (0 error where the reference is 0).
fn output_error_stats(approx: &[f32], exact: &[f32]) -> ErrorStats {
    let mut acc = Welford::new();
    for (&ap, &ex) in approx.iter().zip(exact) {
        let re = if ex == 0.0 {
            0.0
        } else {
            (ap as f64 - ex as f64) / ex as f64
        };
        acc.push(re);
    }
    acc.finish()
}

/// Model-vs-bit-accurate comparison on a real GEMM shape: run `m` and
/// [`Exact`] through the same mantissa pipeline on seeded random
/// matrices (uniform in `[-1, 1)`), and return error statistics of the
/// relative output error over all `rows*cols` elements.
pub fn characterize_matmul(
    m: &dyn Multiplier,
    rows: usize,
    inner: usize,
    cols: usize,
    seed: u64,
) -> Result<ErrorStats> {
    if rows == 0 || inner == 0 || cols == 0 {
        bail!("characterize_matmul: empty shape {rows}x{inner}x{cols}");
    }
    let (a, b) = seeded_matrices(rows, inner, cols, seed);
    let approx = approx_matmul(m, &a, &b, rows, inner, cols)?;
    let exact = approx_matmul(&Exact, &a, &b, rows, inner, cols)?;
    Ok(output_error_stats(&approx, &exact))
}

/// [`characterize_matmul`] over a design set: the operand matrices and
/// the exact-reference GEMM are computed once and shared, instead of
/// once per design. Returns stats in design order.
pub fn characterize_matmul_set(
    designs: &[Box<dyn Multiplier>],
    rows: usize,
    inner: usize,
    cols: usize,
    seed: u64,
) -> Result<Vec<ErrorStats>> {
    if rows == 0 || inner == 0 || cols == 0 {
        bail!("characterize_matmul: empty shape {rows}x{inner}x{cols}");
    }
    let (a, b) = seeded_matrices(rows, inner, cols, seed);
    let exact = approx_matmul(&Exact, &a, &b, rows, inner, cols)?;
    designs
        .iter()
        .map(|d| {
            let approx = approx_matmul(d.as_ref(), &a, &b, rows, inner, cols)?;
            Ok(output_error_stats(&approx, &exact))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mult::{Drum, Mitchell};

    /// f64 reference through the same flush/truncate conventions is
    /// overkill here; instead compare the Exact pipeline against the
    /// native product, which it must match within 1 ulp (truncation vs
    /// round-to-nearest).
    #[test]
    fn exact_pipeline_within_one_ulp_of_native() {
        let mut rng = Xoshiro256::new(17);
        for _ in 0..50_000 {
            let x = f32::from_bits(rng.next_u32());
            let y = f32::from_bits(rng.next_u32());
            if !x.is_normal() || !y.is_normal() {
                continue;
            }
            let native = x * y;
            if !native.is_normal() {
                continue; // overflow/underflow edge conventions differ
            }
            let ours = approx_mul_f32(&Exact, x, y);
            let diff = (ours.to_bits() as i64 - native.to_bits() as i64).abs();
            assert!(diff <= 1, "{x} * {y}: {ours} vs {native} ({diff} ulp)");
        }
    }

    #[test]
    fn powers_of_two_are_exact() {
        for i in -8i32..8 {
            for j in -8i32..8 {
                let (x, y) = (2f32.powi(i), 2f32.powi(j));
                assert_eq!(approx_mul_f32(&Exact, x, y), x * y, "{x}*{y}");
                assert_eq!(approx_mul_f32(&Mitchell, x, y), x * y, "{x}*{y}");
            }
        }
    }

    #[test]
    fn signs_and_zeros() {
        assert_eq!(approx_mul_f32(&Exact, -2.0, 3.0), -6.0);
        assert_eq!(approx_mul_f32(&Exact, -2.0, -3.0), 6.0);
        assert_eq!(approx_mul_f32(&Exact, 0.0, 5.0), 0.0);
        assert!(approx_mul_f32(&Exact, -0.0, 5.0).to_bits() == 0x8000_0000);
        assert!(approx_mul_f32(&Exact, f32::NAN, 5.0).is_nan());
    }

    #[test]
    fn matmul_exact_matches_f64_reference() {
        let (rows, inner, cols) = (7, 13, 5);
        let mut rng = Xoshiro256::new(3);
        let a: Vec<f32> = (0..rows * inner).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
        let b: Vec<f32> = (0..inner * cols).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
        let c = approx_matmul(&Exact, &a, &b, rows, inner, cols).unwrap();
        for i in 0..rows {
            for j in 0..cols {
                let mut want = 0f64;
                for k in 0..inner {
                    want += a[i * inner + k] as f64 * b[k * cols + j] as f64;
                }
                let got = c[i * cols + j] as f64;
                // f32 accumulation + per-product truncation: loose bound.
                assert!(
                    (got - want).abs() < 1e-4 * (1.0 + want.abs()),
                    "c[{i}][{j}] = {got}, want {want}"
                );
            }
        }
    }

    #[test]
    fn matmul_is_deterministic_across_calls() {
        let d = Drum::new(6).unwrap();
        let mut rng = Xoshiro256::new(8);
        let a: Vec<f32> = (0..32 * 24).map(|_| rng.next_f32() - 0.5).collect();
        let b: Vec<f32> = (0..24 * 16).map(|_| rng.next_f32() - 0.5).collect();
        let c1 = approx_matmul(&d, &a, &b, 32, 24, 16).unwrap();
        let c2 = approx_matmul(&d, &a, &b, 32, 24, 16).unwrap();
        assert_eq!(c1, c2);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(approx_matmul(&Exact, &[0.0; 5], &[0.0; 6], 2, 3, 2).is_err());
        assert!(characterize_matmul(&Exact, 0, 3, 2, 1).is_err());
        assert!(characterize_matmul_set(&[], 2, 0, 2, 1).is_err());
    }

    #[test]
    fn matmul_set_matches_individual_runs() {
        let designs: Vec<Box<dyn Multiplier>> =
            vec![Box::new(Exact), Box::new(Drum::new(6).unwrap()), Box::new(Mitchell)];
        let set = characterize_matmul_set(&designs, 8, 16, 8, 3).unwrap();
        assert_eq!(set.len(), designs.len());
        for (d, s) in designs.iter().zip(&set) {
            let solo = characterize_matmul(d.as_ref(), 8, 16, 8, 3).unwrap();
            assert_eq!(s.mre, solo.mre, "{}", d.name());
            assert_eq!(s.sd, solo.sd, "{}", d.name());
        }
    }

    #[test]
    fn gemm_error_tracks_design_error() {
        // DRUM-6's per-product error is ~1.5%; after accumulation over
        // k=32 chains the *output* relative error stays the same order.
        let d = Drum::new(6).unwrap();
        let s = characterize_matmul(&d, 16, 32, 16, 5).unwrap();
        assert_eq!(s.samples, 256);
        assert!(s.mre > 1e-4, "mre {}", s.mre);
        // Upper band is loose: near-zero outputs of a random GEMM
        // legitimately inflate relative error.
        assert!(s.mre < 0.25, "mre {}", s.mre);
        // Exact through the same pipeline: zero error by construction.
        let e = characterize_matmul(&Exact, 16, 32, 16, 5).unwrap();
        assert_eq!(e.mre, 0.0);
    }

    fn transpose(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
        let mut out = vec![0f32; src.len()];
        for r in 0..rows {
            for c in 0..cols {
                out[c * rows + r] = src[r * cols + c];
            }
        }
        out
    }

    #[test]
    fn tn_matches_explicit_transpose_bitwise() {
        // C = Aᵀ·B must be bit-identical to transposing A and running
        // the NN kernel — same products, same accumulation order.
        let (rows, inner, cols) = (9, 14, 6);
        let d = Drum::new(6).unwrap();
        let mut rng = Xoshiro256::new(41);
        // a stored untransposed: [inner x rows]
        let a: Vec<f32> = (0..inner * rows).map(|_| rng.next_f32() - 0.5).collect();
        let b: Vec<f32> = (0..inner * cols).map(|_| rng.next_f32() - 0.5).collect();
        let tn = approx_matmul_tn(&d, &a, &b, rows, inner, cols).unwrap();
        let at = transpose(&a, inner, rows); // [rows x inner]
        let nn = approx_matmul(&d, &at, &b, rows, inner, cols).unwrap();
        assert_eq!(tn, nn);
    }

    #[test]
    fn nt_matches_explicit_transpose_bitwise() {
        let (rows, inner, cols) = (7, 11, 8);
        let d = Mitchell;
        let mut rng = Xoshiro256::new(42);
        let a: Vec<f32> = (0..rows * inner).map(|_| rng.next_f32() - 0.5).collect();
        // b stored untransposed: [cols x inner]
        let b: Vec<f32> = (0..cols * inner).map(|_| rng.next_f32() - 0.5).collect();
        let nt = approx_matmul_nt(&d, &a, &b, rows, inner, cols).unwrap();
        let bt = transpose(&b, cols, inner); // [inner x cols]
        let nn = approx_matmul(&d, &a, &bt, rows, inner, cols).unwrap();
        assert_eq!(nt, nn);
    }

    #[test]
    fn transposed_variants_deterministic_across_calls() {
        // Thread-count independence is inherited from the shared strided
        // kernel (blocks are input-derived; see tests/native_backend.rs
        // for the end-to-end thread sweep). Here: repeat-call identity.
        let d = Drum::new(6).unwrap();
        let mut rng = Xoshiro256::new(43);
        let a: Vec<f32> = (0..24 * 16).map(|_| rng.next_f32() - 0.5).collect();
        let b: Vec<f32> = (0..24 * 12).map(|_| rng.next_f32() - 0.5).collect();
        assert_eq!(
            approx_matmul_tn(&d, &a, &b, 16, 24, 12).unwrap(),
            approx_matmul_tn(&d, &a, &b, 16, 24, 12).unwrap()
        );
        assert_eq!(
            approx_matmul_nt(&d, &b, &a, 12, 24, 16).unwrap(),
            approx_matmul_nt(&d, &b, &a, 12, 24, 16).unwrap()
        );
    }

    #[test]
    fn transposed_variants_reject_bad_shapes() {
        assert!(approx_matmul_tn(&Exact, &[0.0; 5], &[0.0; 6], 2, 3, 2).is_err());
        assert!(approx_matmul_nt(&Exact, &[0.0; 5], &[0.0; 6], 2, 3, 2).is_err());
    }

    #[test]
    fn mitchell_gemm_is_biased_low() {
        // Mitchell underestimates every product, so dot products of
        // same-sign data are biased low — visible at GEMM level.
        let m = Mitchell;
        let mut rng = Xoshiro256::new(4);
        // All-positive matrices keep the bias from cancelling.
        let a: Vec<f32> = (0..8 * 64).map(|_| rng.next_f32() + 0.1).collect();
        let b: Vec<f32> = (0..64 * 8).map(|_| rng.next_f32() + 0.1).collect();
        let approx = approx_matmul(&m, &a, &b, 8, 64, 8).unwrap();
        let exact = approx_matmul(&Exact, &a, &b, 8, 64, 8).unwrap();
        let mean_re: f64 = approx
            .iter()
            .zip(&exact)
            .map(|(&ap, &ex)| (ap as f64 - ex as f64) / ex as f64)
            .sum::<f64>()
            / exact.len() as f64;
        assert!(mean_re < -0.01, "mean relative error {mean_re}");
        assert!(mean_re > -0.12, "mean relative error {mean_re}");
    }
}
