//! Multiplier specification strings — the knob a *training run* turns.
//!
//! The paper's training path only ever knew one number (the Gaussian
//! sigma). A [`MultSpec`] names the actual multiplier a run trains
//! with, so the coordinator, CLI, checkpoints and sweeps can all speak
//! the same vocabulary:
//!
//! * `exact` — exact multipliers;
//! * `gaussian:<sigma>` — the paper's simulation model: each weight
//!   matrix is perturbed `W * (1 + sigma*eps)` (weight-level injection,
//!   Figure 3). This is the only spec the PJRT backend can express,
//!   because the compiled graphs take sigma as a runtime scalar;
//! * any [`by_name`] design spec (`drum6`, `mitchell`, `trunc8`,
//!   `lut12:drum6`, ...) — a bit-accurate unsigned design. The native
//!   backend routes **every forward and backward GEMM** through
//!   [`crate::mult::approx_matmul`] with this design (product-level
//!   injection, what the hardware actually does);
//! * any [`super::signed::by_name`] design spec (`sdrum6`, `booth8`,
//!   `sroba`, `slut12:sdrum6`, ...) — a bit-accurate **signed** design:
//!   the native backend runs the signed GEMM pipeline, where operand
//!   signs go through the multiplier instead of the exponent
//!   bookkeeping ([`MultSpec::build_gemm`] resolves which pipeline a
//!   spec belongs to).
//!
//! The product-level `gauss<pct>` model ([`super::GaussianModel`]) is
//! deliberately rejected here: its noise counter is consumed in thread
//! order, so training with it would not be reproducible. Use
//! `gaussian:<sigma>` (deterministic Threefry weight-level fields) or a
//! deterministic design instead. (`mult::by_name` accepts both
//! spellings, because characterization has no reproducibility stake in
//! per-call pairing — only training does.)

use anyhow::{bail, Context, Result};

use crate::HALF_NORMAL_MEAN;

use super::signed::{self, SignedLut};
use super::{by_name, Exact, GemmDesign, LutMultiplier, Multiplier};

/// A parsed multiplier specification. See the module docs for the
/// grammar.
#[derive(Debug, Clone, PartialEq)]
pub enum MultSpec {
    /// Exact multipliers.
    Exact,
    /// The paper's Gaussian surrogate: weight-level `W*(1+sigma*eps)`.
    Gaussian {
        /// SD of the relative error (fraction, not percent).
        sigma: f64,
    },
    /// A bit-accurate design accepted by [`by_name`].
    Design {
        /// The validated spec string, e.g. `drum6` or `lut12:drum6`.
        spec: String,
    },
}

impl MultSpec {
    /// Parse a spec string (`exact` | `gaussian:<sigma>` | design spec).
    pub fn parse(s: &str) -> Result<MultSpec> {
        let s = s.trim();
        if s == "exact" {
            return Ok(MultSpec::Exact);
        }
        if let Some(v) = s.strip_prefix("gaussian:").or_else(|| s.strip_prefix("gauss:")) {
            let sigma: f64 = v
                .parse()
                .with_context(|| format!("bad gaussian sigma in {s:?}"))?;
            return Self::gaussian_checked(sigma);
        }
        if s.starts_with("gauss") {
            bail!(
                "product-level spec {s:?} is not reproducible under parallel \
                 training; use gaussian:<sigma> (weight-level) instead — \
                 gauss<pct> remains valid in the characterization grammar \
                 (mult::by_name), which has no training-order stake"
            );
        }
        // Validate eagerly so config errors surface at parse time, not
        // mid-run.
        validate_design(s)?;
        Ok(MultSpec::Design { spec: s.to_string() })
    }

    /// Gaussian surrogate at SD `sigma` (`0` normalizes to `Exact`).
    /// Range checking happens at spec parse / config validation, so an
    /// out-of-range sigma surfaces as an error there, never a panic.
    pub fn gaussian(sigma: f64) -> MultSpec {
        if sigma == 0.0 {
            MultSpec::Exact
        } else {
            MultSpec::Gaussian { sigma }
        }
    }

    /// Gaussian surrogate hitting MRE `mre` (`MRE = sigma*sqrt(2/pi)`).
    pub fn gaussian_mre(mre: f64) -> MultSpec {
        Self::gaussian(mre / HALF_NORMAL_MEAN)
    }

    /// Exact multipliers.
    pub fn exact() -> MultSpec {
        MultSpec::Exact
    }

    fn gaussian_checked(sigma: f64) -> Result<MultSpec> {
        if !(0.0..1.0).contains(&sigma) {
            bail!("gaussian sigma {sigma} out of sane range [0, 1)");
        }
        if sigma == 0.0 {
            return Ok(MultSpec::Exact);
        }
        Ok(MultSpec::Gaussian { sigma })
    }

    pub fn is_exact(&self) -> bool {
        matches!(self, MultSpec::Exact)
    }

    /// Gaussian SD this spec injects at the weight level (`0` for exact
    /// and for bit-accurate designs, whose error is operand-dependent).
    pub fn sigma(&self) -> f64 {
        match self {
            MultSpec::Gaussian { sigma } => *sigma,
            _ => 0.0,
        }
    }

    /// MRE of the Gaussian surrogate (`0` for exact / designs).
    pub fn mre(&self) -> f64 {
        self.sigma() * HALF_NORMAL_MEAN
    }

    /// The sigma scalar the compiled PJRT graphs can realize, or `None`
    /// for bit-accurate designs (which need the native backend).
    pub fn surrogate_sigma(&self) -> Option<f64> {
        match self {
            MultSpec::Exact => Some(0.0),
            MultSpec::Gaussian { sigma } => Some(*sigma),
            MultSpec::Design { .. } => None,
        }
    }

    /// Canonical spec string — round-trips through [`MultSpec::parse`];
    /// checkpoints store this.
    pub fn canonical(&self) -> String {
        match self {
            MultSpec::Exact => "exact".to_string(),
            MultSpec::Gaussian { sigma } => format!("gaussian:{sigma}"),
            MultSpec::Design { spec } => spec.clone(),
        }
    }

    /// Filesystem-safe form of [`MultSpec::canonical`] for run tags.
    pub fn file_tag(&self) -> String {
        self.canonical().replace(':', "_")
    }

    /// Human label for tables.
    pub fn label(&self) -> String {
        match self {
            MultSpec::Exact => "exact".to_string(),
            MultSpec::Gaussian { sigma } => format!(
                "MRE ~{:.2}% (SD {:.2}%)",
                100.0 * sigma * HALF_NORMAL_MEAN,
                100.0 * sigma
            ),
            MultSpec::Design { spec } => spec.clone(),
        }
    }

    /// Whether this spec names a **signed** design (two's-complement
    /// pipeline; see [`super::signed`]). Purely syntactic — the signed
    /// and unsigned grammars never overlap.
    pub fn is_signed_design(&self) -> bool {
        matches!(self, MultSpec::Design { spec } if signed::is_signed_spec(spec))
    }

    /// Instantiate the bit-accurate **unsigned** multiplier behind this
    /// spec. The Gaussian surrogate has no product multiplier — it is
    /// weight-level by construction — and signed designs live in the
    /// signed pipeline ([`MultSpec::build_gemm`]); both are errors here.
    pub fn build(&self) -> Result<Box<dyn Multiplier>> {
        match self {
            MultSpec::Exact => Ok(Box::new(Exact)),
            MultSpec::Design { spec } if signed::is_signed_spec(spec) => bail!(
                "{spec:?} is a signed design; build it with MultSpec::build_gemm \
                 (or mult::signed::by_name)"
            ),
            MultSpec::Design { spec } => by_name(spec),
            MultSpec::Gaussian { .. } => bail!(
                "{:?} is a weight-level surrogate, not a product multiplier",
                self.canonical()
            ),
        }
    }

    /// Instantiate the GEMM design behind this spec in its native
    /// operand domain — unsigned or signed ([`GemmDesign`] carries
    /// which). This is what the native backend trains with; the
    /// Gaussian surrogate still has no product multiplier.
    pub fn build_gemm(&self) -> Result<GemmDesign> {
        match self {
            MultSpec::Exact => Ok(GemmDesign::Unsigned(Box::new(Exact))),
            MultSpec::Design { spec } => GemmDesign::by_name(spec),
            MultSpec::Gaussian { .. } => bail!(
                "{:?} is a weight-level surrogate, not a product multiplier",
                self.canonical()
            ),
        }
    }
}

/// Grammar-only validation of a design spec: LUT wrappers (unsigned
/// `lut` and signed `slut` alike) are checked structurally (width range
/// + inner spec) *without* tabulating — a 12-bit table is 128 MiB and
/// ~16.7M simulated products, far too heavy to build and discard at
/// config-parse time. Non-LUT specs are cheap, so [`by_name`] /
/// [`signed::by_name`] stay the single source of truth for them.
fn validate_design(spec: &str) -> Result<()> {
    if signed::is_signed_spec(spec) {
        return validate_signed_design(spec);
    }
    if let Some(rest) = spec.strip_prefix("lut") {
        if let Some((bits, inner)) = rest.split_once(':') {
            let bits: u32 = bits
                .parse()
                .with_context(|| format!("bad LUT width in {spec:?}"))?;
            if !(2..=LutMultiplier::MAX_BITS).contains(&bits) {
                bail!(
                    "LUT operand width must be in [2, {}], got {bits}",
                    LutMultiplier::MAX_BITS
                );
            }
            if signed::is_signed_spec(inner) {
                bail!(
                    "lut wraps unsigned designs; {inner:?} is signed \
                     (use slut{bits}:{inner} for the signed table)"
                );
            }
            return validate_design(inner);
        }
    }
    by_name(spec).map(|_| ())
}

/// Signed arm of [`validate_design`], same structural-LUT discipline.
fn validate_signed_design(spec: &str) -> Result<()> {
    if let Some(rest) = spec.strip_prefix("slut") {
        if let Some((bits, inner)) = rest.split_once(':') {
            let bits: u32 = bits
                .parse()
                .with_context(|| format!("bad signed LUT width in {spec:?}"))?;
            if !(2..=SignedLut::MAX_BITS).contains(&bits) {
                bail!(
                    "signed LUT operand width must be in [2, {}], got {bits}",
                    SignedLut::MAX_BITS
                );
            }
            if !signed::is_signed_spec(inner) {
                bail!(
                    "slut wraps signed designs; {inner:?} is unsigned \
                     (use lut{bits}:{inner} for the unsigned table)"
                );
            }
            return validate_signed_design(inner);
        }
    }
    signed::by_name(spec).map(|_| ())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_forms() {
        assert_eq!(MultSpec::parse("exact").unwrap(), MultSpec::Exact);
        assert_eq!(
            MultSpec::parse("gaussian:0.045").unwrap(),
            MultSpec::Gaussian { sigma: 0.045 }
        );
        assert_eq!(
            MultSpec::parse("drum6").unwrap(),
            MultSpec::Design { spec: "drum6".into() }
        );
        assert_eq!(
            MultSpec::parse("lut12:drum6").unwrap(),
            MultSpec::Design { spec: "lut12:drum6".into() }
        );
        assert!(MultSpec::parse("bogus").is_err());
        assert!(MultSpec::parse("gaussian:1.5").is_err());
        assert!(MultSpec::parse("gauss4.5").is_err()); // product-level, rejected
        // LUT grammar is checked structurally, without tabulating.
        assert!(MultSpec::parse("lut99:drum6").is_err());
        assert!(MultSpec::parse("lut8:bogus").is_err());
        assert!(MultSpec::parse("lut8:lut4:drum6").is_ok()); // nested wrappers
    }

    #[test]
    fn parses_signed_designs() {
        for s in ["sdrum6", "booth8", "sroba", "sexact", "slut12:sdrum6"] {
            let spec = MultSpec::parse(s).unwrap();
            assert_eq!(spec, MultSpec::Design { spec: s.into() }, "{s}");
            assert!(spec.is_signed_design(), "{s}");
            assert_eq!(spec.canonical(), s);
            // Designs have operand-dependent error: no surrogate sigma.
            assert_eq!(spec.surrogate_sigma(), None, "{s}");
        }
        assert!(!MultSpec::parse("drum6").unwrap().is_signed_design());
        assert!(MultSpec::parse("sdrum").is_err());
        assert!(MultSpec::parse("booth99").is_err());
        // Signed LUT grammar is structural too, and signed-only.
        assert!(MultSpec::parse("slut99:sdrum6").is_err());
        assert!(MultSpec::parse("slut8:drum6").is_err());
        assert!(MultSpec::parse("slut8:slut4:sdrum6").is_ok());
        assert!(MultSpec::parse("lut8:sdrum6").is_err()); // signed inner in unsigned LUT
    }

    #[test]
    fn product_level_gauss_error_points_at_the_other_grammar() {
        let err = MultSpec::parse("gauss4.5").unwrap_err().to_string();
        assert!(err.contains("gaussian:<sigma>"), "{err}");
        assert!(err.contains("mult::by_name"), "{err}");
    }

    #[test]
    fn build_gemm_resolves_both_domains() {
        match MultSpec::parse("drum6").unwrap().build_gemm().unwrap() {
            GemmDesign::Unsigned(m) => assert_eq!(m.name(), "drum6"),
            GemmDesign::Signed(_) => panic!("drum6 resolved signed"),
        }
        match MultSpec::parse("booth8").unwrap().build_gemm().unwrap() {
            GemmDesign::Signed(m) => assert_eq!(m.name(), "booth8"),
            GemmDesign::Unsigned(_) => panic!("booth8 resolved unsigned"),
        }
        assert!(MultSpec::gaussian(0.1).build_gemm().is_err());
        // The unsigned-only builder refuses signed specs with a hint.
        let err = MultSpec::parse("sdrum6").unwrap().build().unwrap_err();
        assert!(err.to_string().contains("build_gemm"), "{err:#}");
    }

    #[test]
    fn canonical_roundtrips() {
        for s in ["exact", "gaussian:0.045", "drum6", "mitchell", "lut8:drum6"] {
            let spec = MultSpec::parse(s).unwrap();
            assert_eq!(MultSpec::parse(&spec.canonical()).unwrap(), spec, "{s}");
        }
    }

    #[test]
    fn zero_sigma_normalizes_to_exact() {
        assert!(MultSpec::gaussian(0.0).is_exact());
        assert!(MultSpec::parse("gaussian:0").unwrap().is_exact());
        assert_eq!(MultSpec::gaussian(0.0).canonical(), "exact");
    }

    #[test]
    fn sigma_and_surrogate() {
        let g = MultSpec::gaussian(0.12);
        assert_eq!(g.sigma(), 0.12);
        assert_eq!(g.surrogate_sigma(), Some(0.12));
        assert!((g.mre() - 0.12 * crate::HALF_NORMAL_MEAN).abs() < 1e-12);
        let d = MultSpec::parse("drum6").unwrap();
        assert_eq!(d.sigma(), 0.0);
        assert_eq!(d.surrogate_sigma(), None);
        assert_eq!(MultSpec::Exact.surrogate_sigma(), Some(0.0));
    }

    #[test]
    fn builds_designs_not_gaussian() {
        assert_eq!(MultSpec::parse("drum6").unwrap().build().unwrap().name(), "drum6");
        assert_eq!(MultSpec::Exact.build().unwrap().name(), "exact");
        assert!(MultSpec::gaussian(0.1).build().is_err());
    }

    #[test]
    fn file_tag_is_path_safe() {
        assert_eq!(MultSpec::parse("lut12:drum6").unwrap().file_tag(), "lut12_drum6");
        assert_eq!(MultSpec::gaussian(0.045).file_tag(), "gaussian_0.045");
    }

    #[test]
    fn gaussian_mre_inverts() {
        let s = MultSpec::gaussian_mre(0.036);
        assert!((s.mre() - 0.036).abs() < 1e-12);
    }
}
