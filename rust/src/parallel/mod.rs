//! Minimal data-parallel substrate (the offline environment has no
//! `rayon`; `std::thread::scope` gives us the same fork-join shape with
//! zero dependencies).
//!
//! Design rules, shared by every caller ([`crate::mult::characterize`],
//! [`crate::mult::approx_matmul`], the Table-II sweep):
//!
//! * **Work is split by the problem, never by the worker count.** Item
//!   lists and chunk schedules depend only on the input, so the set of
//!   computed results is identical at any parallelism level; callers
//!   merge results in item order, which makes the *values*
//!   thread-count-independent too.
//! * **Workers steal indices from one atomic counter** — coarse,
//!   contention-free load balancing with no queues to tune.
//! * **Panics propagate**: a panicking worker aborts the scope and
//!   re-panics on the caller, so property tests see their assertions.
//!
//! The `simd` cargo feature changes none of this: vector microkernels
//! replace the *per-task computation* inside a chunk, never the chunk
//! schedule or merge order, so thread-count invariance and simd-on ≡
//! simd-off bit-identity compose (`tests/simd_parity.rs` pins both).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Process-wide override for [`max_threads`] (0 = no override). The
/// CLI's `--threads` flag and tests use this; the `APPROXMUL_THREADS`
/// environment variable is consulted next.
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Force the worker count for subsequent parallel calls (0 clears the
/// override).
pub fn set_max_threads(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::Relaxed);
}

/// Worker count used by parallel helpers: the [`set_max_threads`]
/// override, else `APPROXMUL_THREADS`, else the machine's available
/// parallelism.
pub fn max_threads() -> usize {
    let forced = THREAD_OVERRIDE.load(Ordering::Relaxed);
    if forced > 0 {
        return forced;
    }
    if let Ok(v) = std::env::var("APPROXMUL_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f` to every item on up to `threads` workers and return the
/// results **in item order**. `f` receives `(index, &item)`; it must be
/// pure with respect to ordering — workers claim indices dynamically.
///
/// With `threads <= 1` (or one item) this degrades to a plain
/// sequential map on the calling thread, which — combined with
/// input-derived work splitting — is what makes callers' results
/// reproducible at any parallelism level.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = AtomicUsize::new(0);
    // One slot per item: each is locked by exactly one worker, so the
    // "lock" is uncontended bookkeeping, not synchronization.
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i, &items[i]);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .unwrap()
                .expect("par_map: worker exited without filling its slot")
        })
        .collect()
}

/// Split `data` into contiguous `chunk_len`-sized pieces (the last may
/// be short), apply `f` to each on up to `threads` workers, and return
/// the per-chunk results **in chunk order**. The chunk schedule depends
/// only on `(data.len(), chunk_len)` — never on the worker count — and
/// each chunk is claimed and written by exactly one worker, so callers
/// that fill an output buffer in place inherit the same thread-count
/// independence as [`par_map`] without a gather/concat copy.
///
/// # Panics
/// Panics when `chunk_len == 0`.
pub fn par_chunks_mut<T, R, F>(
    data: &mut [T],
    chunk_len: usize,
    threads: usize,
    f: F,
) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut [T]) -> R + Sync,
{
    assert!(chunk_len > 0, "par_chunks_mut: zero chunk length");
    if data.is_empty() {
        return Vec::new();
    }
    let n = data.len().div_ceil(chunk_len);
    let threads = threads.clamp(1, n);
    if threads <= 1 {
        return data
            .chunks_mut(chunk_len)
            .enumerate()
            .map(|(i, c)| f(i, c))
            .collect();
    }
    let next = AtomicUsize::new(0);
    // One slot per chunk: the claiming worker takes the chunk out and
    // leaves the result behind — uncontended bookkeeping, like par_map.
    type Slot<'s, T, R> = Mutex<(Option<&'s mut [T]>, Option<R>)>;
    let slots: Vec<Slot<'_, T, R>> = data
        .chunks_mut(chunk_len)
        .map(|c| Mutex::new((Some(c), None)))
        .collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let mut slot = slots[i].lock().unwrap();
                let chunk = slot.0.take().expect("par_chunks_mut: chunk claimed twice");
                let r = f(i, chunk);
                slot.1 = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|s| {
            s.into_inner()
                .unwrap()
                .1
                .expect("par_chunks_mut: worker exited without filling its slot")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_item_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = par_map(&items, 8, |i, &x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn sequential_and_parallel_agree() {
        let items: Vec<u64> = (0..257).collect();
        let f = |_: usize, &x: &u64| x.wrapping_mul(0x9E37_79B9).rotate_left(7);
        let seq = par_map(&items, 1, f);
        let par = par_map(&items, 7, f);
        assert_eq!(seq, par);
    }

    #[test]
    fn empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(par_map(&empty, 4, |_, &x| x).is_empty());
        assert_eq!(par_map(&[42u32], 4, |_, &x| x + 1), vec![43]);
    }

    #[test]
    fn override_wins() {
        set_max_threads(3);
        assert_eq!(max_threads(), 3);
        set_max_threads(0);
        assert!(max_threads() >= 1);
    }

    #[test]
    fn chunks_mut_fills_in_place_at_any_thread_count() {
        let fill = |threads: usize| {
            let mut data = vec![0u64; 1003];
            let partials = par_chunks_mut(&mut data, 64, threads, |ci, chunk| {
                for (k, v) in chunk.iter_mut().enumerate() {
                    *v = (ci * 64 + k) as u64 * 3 + 1;
                }
                chunk.iter().sum::<u64>()
            });
            (data, partials)
        };
        let (d1, p1) = fill(1);
        let (d4, p4) = fill(4);
        assert_eq!(d1, d4);
        assert_eq!(p1, p4);
        assert_eq!(p1.len(), 1003usize.div_ceil(64));
        assert!(d1.iter().enumerate().all(|(i, &v)| v == i as u64 * 3 + 1));
        // Empty input: no chunks, no results.
        let mut empty: Vec<u64> = vec![];
        assert!(par_chunks_mut(&mut empty, 8, 4, |_, _| 0u64).is_empty());
    }

    #[test]
    fn worker_panic_propagates() {
        let items: Vec<u32> = (0..64).collect();
        let result = std::panic::catch_unwind(|| {
            par_map(&items, 4, |_, &x| {
                assert!(x != 13, "boom");
                x
            })
        });
        assert!(result.is_err());
    }
}
