//! # approxmul — Deep Learning Training with Simulated Approximate Multipliers
//!
//! Production reproduction of Hammad, El-Sankary & Gu (IEEE ROBIO 2019,
//! DOI 10.1109/ROBIO49542.2019.8961780): CNN training under simulated
//! approximate-multiplier error, plus the paper's hybrid
//! approximate-then-exact training methodology.
//!
//! ## Architecture
//!
//! Training executes on a pluggable backend ([`runtime::Backend`]):
//!
//! * **Native** ([`runtime::NativeBackend`]) — pure-Rust CNN
//!   forward/backward in which every GEMM routes through the
//!   bit-accurate multiplier engine ([`mult::approx_matmul`]): real
//!   designs (`drum6`, `mitchell`, `lut12:drum6`, ...) train real
//!   networks on stock hardware, no artifacts needed.
//! * **PJRT** ([`runtime::PjrtBackend`]) — AOT-lowered XLA graphs from
//!   the Python build layer (`python/compile/`): L1 Pallas error
//!   kernels, L2 JAX model, lowered by `make artifacts`.
//!
//! Around the backends: the training orchestrator and hybrid switch
//! controller ([`coordinator`]), bit-accurate approximate-multiplier
//! simulations ([`mult`]), the hardware cost model ([`costmodel`]),
//! data pipeline ([`data`]), checkpointing ([`checkpoint`]), metrics
//! ([`metrics`]) and reporting ([`report`]).
//!
//! ## Quickstart (native backend — runs anywhere)
//!
//! ```no_run
//! use approxmul::config::{ExperimentConfig, MultiplierPolicy};
//! use approxmul::coordinator::Trainer;
//! use approxmul::mult::MultSpec;
//!
//! let mut cfg = ExperimentConfig::preset_tiny();
//! cfg.policy = MultiplierPolicy::Approximate {
//!     mult: MultSpec::parse("drum6")?,
//! };
//! let result = Trainer::native(cfg)?.run()?;
//! println!("final accuracy {:.2}%", 100.0 * result.best_accuracy);
//! # anyhow::Result::<()>::Ok(())
//! ```
//!
//! The `approxmul` binary exposes the paper's experiments as subcommands
//! (`table2`, `table3`, `fig2`, `arch`, `characterize`, `costmodel`,
//! `train`); see `approxmul --help`.

// The `simd` feature builds explicit vector microkernels on
// `std::simd` (nightly portable_simd). Feature-off builds are
// unchanged stable Rust.
#![cfg_attr(feature = "simd", feature(portable_simd))]

pub mod benchkit;
pub mod checkpoint;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod error_model;
pub mod json;
pub mod metrics;
pub mod mult;
pub mod parallel;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod testkit;

/// Crate-wide result type (thin alias over `anyhow`).
pub type Result<T> = anyhow::Result<T>;

/// `MRE = SD * sqrt(2/pi)` — the identity every (MRE, SD) pair in the
/// paper satisfies; `error_model` and the Python side share it.
pub const HALF_NORMAL_MEAN: f64 = 0.797_884_560_802_865_4;
