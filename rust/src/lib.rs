//! # approxmul — Deep Learning Training with Simulated Approximate Multipliers
//!
//! Production reproduction of Hammad, El-Sankary & Gu (IEEE ROBIO 2019,
//! DOI 10.1109/ROBIO49542.2019.8961780): CNN training under simulated
//! approximate-multiplier error, plus the paper's hybrid
//! approximate-then-exact training methodology.
//!
//! ## Architecture (three layers, Python never on the hot path)
//!
//! * **L1 (Pallas, build time)** — `python/compile/kernels/`: the
//!   approximate-multiplier error kernels (weight-level and per-product).
//! * **L2 (JAX, build time)** — `python/compile/model.py`: VGG-style CNN
//!   fwd/bwd + SGD, AOT-lowered to HLO text artifacts by `make artifacts`.
//! * **L3 (this crate)** — loads the artifacts via PJRT ([`runtime`]) and
//!   owns everything else: the training orchestrator and hybrid switch
//!   controller ([`coordinator`]), bit-accurate approximate-multiplier
//!   simulations ([`mult`]), the hardware cost model ([`costmodel`]),
//!   data pipeline ([`data`]), checkpointing ([`checkpoint`]), metrics
//!   ([`metrics`]) and reporting ([`report`]).
//!
//! ## Quickstart
//!
//! ```no_run
//! use approxmul::config::ExperimentConfig;
//! use approxmul::coordinator::Trainer;
//! use approxmul::runtime::Engine;
//!
//! let engine = Engine::from_artifacts("artifacts")?;
//! let cfg = ExperimentConfig::preset_small();
//! let mut trainer = Trainer::new(&engine, cfg)?;
//! let result = trainer.run()?;
//! println!("final accuracy {:.2}%", 100.0 * result.best_accuracy);
//! # anyhow::Result::<()>::Ok(())
//! ```
//!
//! The `approxmul` binary exposes the paper's experiments as subcommands
//! (`table2`, `table3`, `fig2`, `arch`, `characterize`, `costmodel`,
//! `train`); see `approxmul --help`.

pub mod benchkit;
pub mod checkpoint;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod error_model;
pub mod json;
pub mod metrics;
pub mod mult;
pub mod parallel;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod tensor;
pub mod testkit;

/// Crate-wide result type (thin alias over `anyhow`).
pub type Result<T> = anyhow::Result<T>;

/// `MRE = SD * sqrt(2/pi)` — the identity every (MRE, SD) pair in the
/// paper satisfies; `error_model` and the Python side share it.
pub const HALF_NORMAL_MEAN: f64 = 0.797_884_560_802_865_4;
