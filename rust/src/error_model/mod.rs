//! The paper's Gaussian approximate-multiplier error model, host side.
//!
//! Mirrors `python/compile/error_model.py`: SD (`sigma`) is the
//! canonical knob, `MRE = sigma * sqrt(2/pi)`. This module also
//! regenerates error matrices bit-identically to what the compiled
//! graphs inject (same Threefry streams), which powers the Figure-2
//! histogram harness and the model-vs-bit-accurate comparisons.

use crate::rng::threefry::counter_normal;
use crate::HALF_NORMAL_MEAN;

/// Convert Gaussian sigma (the paper's "SD") to MRE.
pub fn sigma_to_mre(sigma: f64) -> f64 {
    sigma * HALF_NORMAL_MEAN
}

/// Convert MRE to the Gaussian sigma realizing it.
pub fn mre_to_sigma(mre: f64) -> f64 {
    mre / HALF_NORMAL_MEAN
}

/// One error configuration (a Table II column pair).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ErrorConfig {
    /// Gaussian SD of the relative error (fraction, not percent).
    pub sigma: f64,
}

impl ErrorConfig {
    pub fn from_sigma(sigma: f64) -> Self {
        ErrorConfig { sigma }
    }

    pub fn from_mre(mre: f64) -> Self {
        ErrorConfig { sigma: mre_to_sigma(mre) }
    }

    pub fn exact() -> Self {
        ErrorConfig { sigma: 0.0 }
    }

    pub fn is_exact(&self) -> bool {
        self.sigma == 0.0
    }

    pub fn mre(&self) -> f64 {
        sigma_to_mre(self.sigma)
    }

    /// Display label like "MRE ~1.4% (SD 1.8%)".
    pub fn label(&self) -> String {
        if self.is_exact() {
            "exact".to_string()
        } else {
            format!("MRE ~{:.2}% (SD {:.2}%)", 100.0 * self.mre(), 100.0 * self.sigma)
        }
    }
}

/// [`paper_table2_configs`] as multiplier specs (id, spec, paper
/// accuracy %) — the shape the sweep and hybrid search consume.
pub fn paper_table2_specs() -> Vec<(u32, crate::mult::MultSpec, f64)> {
    paper_table2_configs()
        .into_iter()
        .map(|(id, c, acc)| (id, crate::mult::MultSpec::gaussian(c.sigma), acc))
        .collect()
}

/// The paper's Table II error configurations (id, config, paper accuracy %).
pub fn paper_table2_configs() -> Vec<(u32, ErrorConfig, f64)> {
    [
        (0, 0.000, 93.60),
        (1, 0.015, 93.59),
        (2, 0.018, 93.53),
        (3, 0.030, 93.35),
        (4, 0.045, 93.23),
        (5, 0.060, 93.11),
        (6, 0.120, 93.00),
        (7, 0.240, 92.23),
        (8, 0.480, 65.65),
    ]
    .into_iter()
    .map(|(id, sd, acc)| (id, ErrorConfig::from_sigma(sd), acc))
    .collect()
}

/// An error matrix for one layer — the exact field the compiled graph
/// multiplies into that layer's weights for `(seed, stream=layer_id)`.
#[derive(Debug, Clone)]
pub struct ErrorMatrix {
    /// The multiplicative factors `1 + sigma*eps` (len = weight count).
    pub factors: Vec<f32>,
    pub sigma: f64,
}

impl ErrorMatrix {
    /// Generate the matrix the graph will inject for this layer.
    pub fn generate(seed: u32, layer_stream: u32, sigma: f64, n: usize) -> Self {
        let eps = counter_normal(seed, layer_stream, 0, n);
        ErrorMatrix {
            factors: eps.iter().map(|&e| 1.0 + (sigma as f32) * e).collect(),
            sigma,
        }
    }

    /// Measured MRE of the realized matrix (mean |factor - 1|).
    pub fn measured_mre(&self) -> f64 {
        if self.factors.is_empty() {
            return 0.0;
        }
        self.factors.iter().map(|&f| (f as f64 - 1.0).abs()).sum::<f64>()
            / self.factors.len() as f64
    }

    /// Measured SD of the realized relative error.
    pub fn measured_sd(&self) -> f64 {
        if self.factors.is_empty() {
            return 0.0;
        }
        let mean: f64 = self.factors.iter().map(|&f| f as f64 - 1.0).sum::<f64>()
            / self.factors.len() as f64;
        (self
            .factors
            .iter()
            .map(|&f| (f as f64 - 1.0 - mean).powi(2))
            .sum::<f64>()
            / self.factors.len() as f64)
            .sqrt()
    }

    /// Histogram of the relative errors over `bins` equal-width bins in
    /// `[lo, hi]` — the Figure-2 reproduction. Returns (bin_edges_lo,
    /// counts); out-of-range samples clamp into the edge bins.
    pub fn histogram(&self, bins: usize, lo: f64, hi: f64) -> (Vec<f64>, Vec<u64>) {
        assert!(bins >= 2 && hi > lo);
        let width = (hi - lo) / bins as f64;
        let mut counts = vec![0u64; bins];
        for &f in &self.factors {
            let re = f as f64 - 1.0;
            let idx = (((re - lo) / width) as isize).clamp(0, bins as isize - 1);
            counts[idx as usize] += 1;
        }
        let edges = (0..bins).map(|i| lo + i as f64 * width).collect();
        (edges, counts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_roundtrip() {
        for mre in [0.012, 0.036, 0.382] {
            assert!((sigma_to_mre(mre_to_sigma(mre)) - mre).abs() < 1e-12);
        }
    }

    #[test]
    fn paper_pairs_satisfy_identity() {
        // Every Table II (MRE, SD) pair: MRE = SD * sqrt(2/pi) within
        // the paper's "~" rounding.
        let mres = [0.012, 0.014, 0.024, 0.036, 0.048, 0.096, 0.192, 0.382];
        let sds = [0.015, 0.018, 0.030, 0.045, 0.060, 0.120, 0.240, 0.480];
        for (mre, sd) in mres.iter().zip(&sds) {
            let predicted = sigma_to_mre(*sd);
            assert!(
                (predicted - mre).abs() / mre < 0.05,
                "MRE {mre} vs predicted {predicted}"
            );
        }
    }

    #[test]
    fn generated_matrix_hits_target_stats() {
        let m = ErrorMatrix::generate(42, 3, 0.045, 200_000);
        assert!((m.measured_sd() - 0.045).abs() < 0.0005, "sd {}", m.measured_sd());
        assert!(
            (m.measured_mre() - sigma_to_mre(0.045)).abs() < 0.0005,
            "mre {}",
            m.measured_mre()
        );
    }

    #[test]
    fn histogram_is_centered_and_complete() {
        let m = ErrorMatrix::generate(7, 1, 0.045, 100_000);
        let (edges, counts) = m.histogram(500, -0.2, 0.2);
        assert_eq!(edges.len(), 500);
        assert_eq!(counts.iter().sum::<u64>(), 100_000);
        // Peak near zero error.
        let peak = counts
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .unwrap()
            .0;
        let peak_center = edges[peak] + 0.2 / 500.0;
        assert!(peak_center.abs() < 0.01, "peak at {peak_center}");
    }

    #[test]
    fn exact_config() {
        let c = ErrorConfig::exact();
        assert!(c.is_exact());
        assert_eq!(c.mre(), 0.0);
        assert_eq!(c.label(), "exact");
    }

    #[test]
    fn table2_configs_shape() {
        let t = paper_table2_configs();
        assert_eq!(t.len(), 9);
        assert!(t[0].1.is_exact());
        assert!((t[4].1.mre() - 0.0359).abs() < 0.001); // ~3.6%
    }
}
