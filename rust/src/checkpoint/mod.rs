//! Checkpoint store: CRC-checked binary snapshots of the full training
//! state (params ++ BN state ++ optimizer momentum).
//!
//! The paper's procedures lean on checkpointing twice: Figure 3
//! ("download weights after certain epochs ... resume from that epoch")
//! and the Figure-4 hybrid switch-epoch search, which resumes an exact
//! tail from every candidate epoch of a single approximate run. The
//! format is self-describing so a checkpoint can be inspected and
//! restored without the engine.
//!
//! Layout (little endian):
//! ```text
//! magic "AXMCKPT1" | meta_len u32 | meta json bytes
//! repeat per tensor: name_len u32 | name | dtype u8 | rank u32 |
//!                    dims u64[rank] | payload u32[prod(dims)]
//! crc32 of everything above
//! ```

use std::cell::Cell;
use std::fmt;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::json::Value;
use crate::tensor::{DType, Tensor};

const MAGIC: &[u8; 8] = b"AXMCKPT1";

/// Machine-readable classification of a checkpoint failure. Recovery
/// code dispatches on this ([`classify`]); the human-readable message
/// still carries the file path and byte-level detail.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureClass {
    /// The file does not exist.
    Missing,
    /// The byte stream ends before the declared structure does (files
    /// shorter than the fixed header, or interior length overruns).
    Truncated,
    /// The stored CRC-32 disagrees with the content — bit rot, a torn
    /// write, or mid-file truncation (the tail bytes then parse as a
    /// wrong CRC).
    CrcMismatch,
    /// CRC-valid but structurally nonsense (bad magic/dtype/rank/meta).
    Malformed,
    /// An OS-level I/O error other than not-found.
    Io,
}

impl FailureClass {
    pub fn name(self) -> &'static str {
        match self {
            FailureClass::Missing => "missing",
            FailureClass::Truncated => "truncated",
            FailureClass::CrcMismatch => "crc-mismatch",
            FailureClass::Malformed => "malformed",
            FailureClass::Io => "io",
        }
    }
}

/// Typed checkpoint error carried through `anyhow` chains so callers
/// can recover by class instead of string-matching messages.
#[derive(Debug)]
pub struct CkptFault {
    pub class: FailureClass,
    msg: String,
}

impl fmt::Display for CkptFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for CkptFault {}

fn fault(class: FailureClass, msg: String) -> anyhow::Error {
    anyhow::Error::new(CkptFault { class, msg })
}

/// Walk an error's chain for a checkpoint-fault classification
/// (context layers added by callers are skipped transparently).
pub fn classify(err: &anyhow::Error) -> Option<FailureClass> {
    err.chain()
        .find_map(|c| c.downcast_ref::<CkptFault>())
        .map(|f| f.class)
}

/// Checkpoint metadata (JSON header).
#[derive(Debug, Clone)]
pub struct Meta {
    pub preset: String,
    pub epoch: u64,
    pub step: u64,
    /// Sigma the run was training with when snapshotted (Gaussian
    /// surrogate only; 0 for exact and bit-accurate designs).
    pub sigma: f64,
    /// Canonical multiplier spec in force when snapshotted (`exact`,
    /// `gaussian:<sigma>`, `drum6`, ...) — sigma alone loses the
    /// multiplier's identity.
    pub mult: String,
    /// Free-form tag (e.g. "table2-case4").
    pub tag: String,
    /// Original multiplier spec before the watchdog escalated the run
    /// (None for runs that never escalated). Records that the weights
    /// were *not* trained end-to-end under `mult`.
    pub escalated_from: Option<String>,
}

impl Meta {
    fn to_json(&self) -> Value {
        // `escalated_from` is emitted only when set, so non-escalated
        // checkpoints keep the exact legacy key set.
        let mut pairs = vec![
            ("preset", Value::from(self.preset.as_str())),
            ("epoch", Value::from(self.epoch as usize)),
            ("step", Value::from(self.step as usize)),
            ("sigma", Value::from(self.sigma)),
            ("mult", Value::from(self.mult.as_str())),
            ("tag", Value::from(self.tag.as_str())),
        ];
        if let Some(from) = &self.escalated_from {
            pairs.push(("escalated_from", Value::from(from.as_str())));
        }
        crate::json::object(pairs)
    }

    fn from_json(v: &Value) -> Result<Self> {
        let sigma = v.get("sigma")?.as_f64()?;
        // Pre-backend-split checkpoints have no `mult` key: their only
        // multiplier identity *was* the sigma, so reconstruct it.
        let mult = match v.opt("mult") {
            Some(m) => m.as_str()?.to_string(),
            None if sigma > 0.0 => format!("gaussian:{sigma}"),
            None => "exact".to_string(),
        };
        let escalated_from = match v.opt("escalated_from") {
            Some(e) => Some(e.as_str()?.to_string()),
            None => None,
        };
        Ok(Meta {
            preset: v.get("preset")?.as_str()?.to_string(),
            epoch: v.get("epoch")?.as_i64()? as u64,
            step: v.get("step")?.as_i64()? as u64,
            sigma,
            mult,
            tag: v.get("tag")?.as_str()?.to_string(),
            escalated_from,
        })
    }
}

/// Serialize a checkpoint to bytes.
pub fn to_bytes(meta: &Meta, named: &[(String, &Tensor)]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    let meta_bytes = meta.to_json().to_string().into_bytes();
    out.extend_from_slice(&(meta_bytes.len() as u32).to_le_bytes());
    out.extend_from_slice(&meta_bytes);
    out.extend_from_slice(&(named.len() as u32).to_le_bytes());
    for (name, t) in named {
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        out.push(match t.dtype() {
            DType::F32 => 0,
            DType::I32 => 1,
            DType::U32 => 2,
        });
        out.extend_from_slice(&(t.shape().len() as u32).to_le_bytes());
        for &d in t.shape() {
            out.extend_from_slice(&(d as u64).to_le_bytes());
        }
        for &w in t.raw() {
            out.extend_from_slice(&w.to_le_bytes());
        }
    }
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Little-endian field decodes surfaced as typed faults instead of
/// panics: the resilience spine must never abort on malformed bytes
/// (detlint rule P1), so even the "slice is exactly 4 bytes by
/// construction" conversions go through the classified error path.
fn le_u32(b: &[u8]) -> Result<u32> {
    let arr: [u8; 4] = b.try_into().map_err(|_| {
        fault(
            FailureClass::Truncated,
            format!("u32 field has {} bytes", b.len()),
        )
    })?;
    Ok(u32::from_le_bytes(arr))
}

fn le_u64(b: &[u8]) -> Result<u64> {
    let arr: [u8; 8] = b.try_into().map_err(|_| {
        fault(
            FailureClass::Truncated,
            format!("u64 field has {} bytes", b.len()),
        )
    })?;
    Ok(u64::from_le_bytes(arr))
}

/// Parse checkpoint bytes.
pub fn from_bytes(bytes: &[u8]) -> Result<(Meta, Vec<(String, Tensor)>)> {
    if bytes.len() < MAGIC.len() + 8 {
        return Err(fault(
            FailureClass::Truncated,
            format!("checkpoint truncated ({} bytes)", bytes.len()),
        ));
    }
    let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
    let stored = le_u32(crc_bytes)?;
    let computed = crc32(body);
    if stored != computed {
        return Err(fault(
            FailureClass::CrcMismatch,
            format!("checkpoint CRC mismatch: stored {stored:#10x}, computed {computed:#10x}"),
        ));
    }
    let malformed = |msg: String| fault(FailureClass::Malformed, msg);
    let mut r = Reader { b: body, pos: 0 };
    let magic = r.take(8)?;
    if magic != MAGIC {
        return Err(malformed("bad checkpoint magic".into()));
    }
    let meta_len = r.u32()? as usize;
    let meta_bytes = r.take(meta_len)?;
    let meta_str = std::str::from_utf8(meta_bytes)
        .map_err(|e| malformed(format!("checkpoint meta is not UTF-8: {e}")))?;
    let meta = Value::parse(meta_str)
        .and_then(|v| Meta::from_json(&v))
        .map_err(|e| malformed(format!("bad checkpoint meta: {e}")))?;
    let count = r.u32()? as usize;
    let mut tensors = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = r.u32()? as usize;
        let name = std::str::from_utf8(r.take(name_len)?)
            .map_err(|e| malformed(format!("tensor name is not UTF-8: {e}")))?
            .to_string();
        let dtype = match r.u8()? {
            0 => DType::F32,
            1 => DType::I32,
            2 => DType::U32,
            d => return Err(malformed(format!("bad dtype tag {d}"))),
        };
        let rank = r.u32()? as usize;
        if rank > 8 {
            return Err(malformed(format!("absurd rank {rank}")));
        }
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(r.u64()? as usize);
        }
        let n: usize = dims.iter().product();
        let payload = r.take(n * 4)?;
        let words: Vec<u32> = payload
            .chunks_exact(4)
            .map(le_u32)
            .collect::<Result<Vec<u32>>>()?;
        let t = match dtype {
            DType::F32 => Tensor::from_f32(
                &dims,
                words.iter().map(|&w| f32::from_bits(w)).collect(),
            )?,
            DType::I32 => {
                Tensor::from_i32(&dims, words.iter().map(|&w| w as i32).collect())?
            }
            DType::U32 => Tensor::from_u32(&dims, words)?,
        };
        tensors.push((name, t));
    }
    if r.pos != body.len() {
        return Err(malformed("trailing bytes in checkpoint".into()));
    }
    Ok((meta, tensors))
}

/// Bounds-checked little-endian cursor over checkpoint bytes.
struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let s = self
            .pos
            .checked_add(n)
            .and_then(|end| self.b.get(self.pos..end))
            .ok_or_else(|| {
                fault(
                    FailureClass::Truncated,
                    format!("checkpoint truncated at offset {}", self.pos),
                )
            })?;
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        // detlint: allow(P2) -- take(1) just bounds-checked exactly this byte
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        le_u32(self.take(4)?)
    }

    fn u64(&mut self) -> Result<u64> {
        le_u64(self.take(8)?)
    }
}

/// One-shot injected store failure, armed by the fault harness
/// ([`crate::testkit::faults`]) to exercise recovery paths.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreFault {
    /// Simulate a torn write: the *final* checkpoint path ends up with
    /// only the first `keep` bytes (as after a crash between a
    /// non-durable rename and the data reaching disk).
    TearNextSave { keep: usize },
    /// Simulate a transient I/O failure: leave a partial `.ckpt.tmp`
    /// behind and return a classified `Io` error.
    FailNextSave,
}

/// Disk-backed checkpoint store with epoch-indexed naming.
pub struct Store {
    dir: PathBuf,
    /// Armed fault, consumed by the next `save`. `Cell` because the
    /// store is handed out behind `&self` and never crosses threads.
    fault: Cell<Option<StoreFault>>,
}

impl Store {
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        Ok(Store { dir, fault: Cell::new(None) })
    }

    pub fn path_for(&self, tag: &str, epoch: u64) -> PathBuf {
        self.dir.join(format!("{tag}-epoch{epoch:04}.ckpt"))
    }

    /// Arm (or clear) a one-shot save fault. Test-harness hook.
    pub fn inject_fault(&self, f: Option<StoreFault>) {
        self.fault.set(f);
    }

    /// Write durably and atomically: unique tmp in the same directory,
    /// fsync the file, rename over the final name, then fsync the
    /// directory so the rename itself survives a crash.
    pub fn save(&self, meta: &Meta, named: &[(String, &Tensor)]) -> Result<PathBuf> {
        let path = self.path_for(&meta.tag, meta.epoch);
        // Per-process tmp name: two runs sharing an out-dir must not
        // clobber each other's in-flight writes.
        let tmp = self.dir.join(format!(
            "{}-epoch{:04}.ckpt.{}.tmp",
            meta.tag,
            meta.epoch,
            std::process::id()
        ));
        let bytes = to_bytes(meta, named);
        match self.fault.take() {
            Some(StoreFault::TearNextSave { keep }) => {
                let keep = keep.min(bytes.len());
                // detlint: allow(P2) -- keep is clamped to bytes.len() on the line above
                std::fs::write(&path, &bytes[..keep])
                    .with_context(|| format!("tearing {}", path.display()))?;
                return Ok(path);
            }
            Some(StoreFault::FailNextSave) => {
                // detlint: allow(P2) -- len/2 <= len; injected-fault path writes a half file
                std::fs::write(&tmp, &bytes[..bytes.len() / 2]).ok();
                return Err(fault(
                    FailureClass::Io,
                    format!("injected I/O failure saving {}", path.display()),
                ));
            }
            None => {}
        }
        let io = |msg: String| move |e: std::io::Error| fault(FailureClass::Io, format!("{msg}: {e}"));
        let mut f = std::fs::File::create(&tmp)
            .map_err(io(format!("creating {}", tmp.display())))?;
        f.write_all(&bytes)
            .map_err(io(format!("writing {}", tmp.display())))?;
        f.sync_all()
            .map_err(io(format!("syncing {}", tmp.display())))?;
        drop(f);
        std::fs::rename(&tmp, &path)
            .map_err(io(format!("renaming {} -> {}", tmp.display(), path.display())))?;
        #[cfg(unix)]
        std::fs::File::open(&self.dir)
            .and_then(|d| d.sync_all())
            .map_err(io(format!("syncing directory {}", self.dir.display())))?;
        Ok(path)
    }

    pub fn load(&self, tag: &str, epoch: u64) -> Result<(Meta, Vec<(String, Tensor)>)> {
        self.load_path(&self.path_for(tag, epoch))
    }

    pub fn load_path(&self, path: &Path) -> Result<(Meta, Vec<(String, Tensor)>)> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)
            .map_err(|e| {
                let class = if e.kind() == std::io::ErrorKind::NotFound {
                    FailureClass::Missing
                } else {
                    FailureClass::Io
                };
                fault(class, format!("opening {}: {e}", path.display()))
            })?
            .read_to_end(&mut bytes)
            .map_err(|e| fault(FailureClass::Io, format!("reading {}: {e}", path.display())))?;
        from_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn exists(&self, tag: &str, epoch: u64) -> bool {
        self.path_for(tag, epoch).exists()
    }

    /// Epochs with a (possibly corrupt) checkpoint file for `tag`,
    /// ascending. Stray `.tmp` files are excluded by construction.
    pub fn list_epochs(&self, tag: &str) -> Result<Vec<u64>> {
        let prefix = format!("{tag}-epoch");
        let mut epochs = Vec::new();
        for entry in std::fs::read_dir(&self.dir)
            .with_context(|| format!("listing {}", self.dir.display()))?
        {
            let name = entry?.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some(num) = name
                .strip_prefix(&prefix)
                .and_then(|rest| rest.strip_suffix(".ckpt"))
            {
                if let Ok(e) = num.parse::<u64>() {
                    epochs.push(e);
                }
            }
        }
        epochs.sort_unstable();
        epochs.dedup();
        Ok(epochs)
    }

    /// Newest checkpoint for `tag` that passes the CRC/structure
    /// checks, scanning backward past corrupt, truncated or unreadable
    /// files (each skip is logged with its failure class). `Ok(None)`
    /// when no valid checkpoint exists at all.
    pub fn latest_valid(
        &self,
        tag: &str,
    ) -> Result<Option<(u64, Meta, Vec<(String, Tensor)>)>> {
        for epoch in self.list_epochs(tag)?.into_iter().rev() {
            match self.load(tag, epoch) {
                Ok((meta, tensors)) => return Ok(Some((epoch, meta, tensors))),
                Err(e) => {
                    let class = classify(&e).map(FailureClass::name).unwrap_or("unknown");
                    log::warn!(
                        "skipping checkpoint {} ({class}): {e:#}",
                        self.path_for(tag, epoch).display()
                    );
                }
            }
        }
        Ok(None)
    }

    /// Retention: delete all but the newest `keep` checkpoints for
    /// `tag`, plus any stale tmp files for `tag` left by *other*
    /// processes (dead runs). Returns the number of files removed.
    /// `keep == 0` keeps everything.
    pub fn gc_keep_last(&self, tag: &str, keep: usize) -> Result<usize> {
        let mut removed = 0usize;
        if keep > 0 {
            let epochs = self.list_epochs(tag)?;
            if epochs.len() > keep {
                // detlint: allow(P2) -- len > keep just checked, so len - keep <= len
                for &epoch in &epochs[..epochs.len() - keep] {
                    let p = self.path_for(tag, epoch);
                    std::fs::remove_file(&p)
                        .with_context(|| format!("removing {}", p.display()))?;
                    removed += 1;
                }
            }
        }
        let prefix = format!("{tag}-epoch");
        let my_tmp = format!(".{}.tmp", std::process::id());
        for entry in std::fs::read_dir(&self.dir)
            .with_context(|| format!("listing {}", self.dir.display()))?
        {
            let entry = entry?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if name.starts_with(&prefix) && name.ends_with(".tmp") && !name.ends_with(&my_tmp) {
                std::fs::remove_file(entry.path())
                    .with_context(|| format!("removing stale {name}"))?;
                removed += 1;
            }
        }
        Ok(removed)
    }
}

/// CRC-32 (IEEE 802.3), table-driven.
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut crc = !0u32;
    for &b in bytes {
        // detlint: allow(P2) -- index masked to 0xFF into a 256-entry table
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> (Meta, Vec<(String, Tensor)>) {
        (
            Meta {
                preset: "tiny".into(),
                epoch: 3,
                step: 99,
                sigma: 0.045,
                mult: "gaussian:0.045".into(),
                tag: "unit".into(),
                escalated_from: None,
            },
            vec![
                ("w".into(), Tensor::from_f32(&[2, 2], vec![1., -2., 3., 0.5]).unwrap()),
                ("y".into(), Tensor::from_i32(&[3], vec![1, -1, 7]).unwrap()),
                ("s".into(), Tensor::scalar_u32(42)),
            ],
        )
    }

    #[test]
    fn roundtrip() {
        let (meta, tensors) = sample();
        let named: Vec<(String, &Tensor)> =
            tensors.iter().map(|(n, t)| (n.clone(), t)).collect();
        let bytes = to_bytes(&meta, &named);
        let (m2, t2) = from_bytes(&bytes).unwrap();
        assert_eq!(m2.preset, "tiny");
        assert_eq!(m2.epoch, 3);
        assert_eq!(m2.sigma, 0.045);
        assert_eq!(m2.mult, "gaussian:0.045");
        assert_eq!(t2.len(), 3);
        assert_eq!(t2[0].1.as_f32().unwrap(), vec![1., -2., 3., 0.5]);
        assert_eq!(t2[1].1.as_i32().unwrap(), vec![1, -1, 7]);
    }

    /// A hand-built checkpoint whose JSON header predates the `mult`
    /// key (the old format) must still load, deriving the multiplier
    /// identity from sigma.
    #[test]
    fn legacy_checkpoint_without_mult_loads() {
        let build = |meta_json: &str| -> Vec<u8> {
            let mut bytes = Vec::new();
            bytes.extend_from_slice(MAGIC);
            bytes.extend_from_slice(&(meta_json.len() as u32).to_le_bytes());
            bytes.extend_from_slice(meta_json.as_bytes());
            bytes.extend_from_slice(&0u32.to_le_bytes()); // zero tensors
            let crc = crc32(&bytes);
            bytes.extend_from_slice(&crc.to_le_bytes());
            bytes
        };
        let legacy = build(
            r#"{"epoch":2,"preset":"tiny","sigma":0.12,"step":7,"tag":"old"}"#,
        );
        let (meta, tensors) = from_bytes(&legacy).unwrap();
        assert_eq!(meta.epoch, 2);
        assert_eq!(meta.mult, "gaussian:0.12");
        assert!(tensors.is_empty());
        let exact = build(
            r#"{"epoch":1,"preset":"tiny","sigma":0.0,"step":3,"tag":"old"}"#,
        );
        let (meta, _) = from_bytes(&exact).unwrap();
        assert_eq!(meta.mult, "exact");
    }

    #[test]
    fn corruption_detected() {
        let (meta, tensors) = sample();
        let named: Vec<(String, &Tensor)> =
            tensors.iter().map(|(n, t)| (n.clone(), t)).collect();
        let mut bytes = to_bytes(&meta, &named);
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        assert!(from_bytes(&bytes).is_err());
    }

    #[test]
    fn truncation_detected() {
        let (meta, tensors) = sample();
        let named: Vec<(String, &Tensor)> =
            tensors.iter().map(|(n, t)| (n.clone(), t)).collect();
        let bytes = to_bytes(&meta, &named);
        assert!(from_bytes(&bytes[..bytes.len() - 1]).is_err());
        assert!(from_bytes(&bytes[..10]).is_err());
    }

    #[test]
    fn store_roundtrip() {
        let dir = std::env::temp_dir().join(format!("axm-ckpt-{}", std::process::id()));
        let store = Store::new(&dir).unwrap();
        let (meta, tensors) = sample();
        let named: Vec<(String, &Tensor)> =
            tensors.iter().map(|(n, t)| (n.clone(), t)).collect();
        store.save(&meta, &named).unwrap();
        assert!(store.exists("unit", 3));
        let (m2, t2) = store.load("unit", 3).unwrap();
        assert_eq!(m2.step, 99);
        assert_eq!(t2.len(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn crc_known_answer() {
        // CRC32("123456789") = 0xCBF43926 (classic check value).
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn failure_classification() {
        let (meta, tensors) = sample();
        let named: Vec<(String, &Tensor)> =
            tensors.iter().map(|(n, t)| (n.clone(), t)).collect();
        let bytes = to_bytes(&meta, &named);
        // Sub-header file: truncated.
        let e = from_bytes(&bytes[..10]).unwrap_err();
        assert_eq!(classify(&e), Some(FailureClass::Truncated));
        // Mid-file truncation of a real file: the tail bytes are
        // misread as the CRC, so it classifies as a CRC mismatch.
        let e = from_bytes(&bytes[..bytes.len() / 2]).unwrap_err();
        assert_eq!(classify(&e), Some(FailureClass::CrcMismatch));
        // Payload bit flip: CRC mismatch.
        let mut flipped = bytes.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x01;
        let e = from_bytes(&flipped).unwrap_err();
        assert_eq!(classify(&e), Some(FailureClass::CrcMismatch));
        // Valid CRC over garbage magic: malformed.
        let mut body = bytes[..bytes.len() - 4].to_vec();
        body[0] ^= 0xFF;
        let crc = crc32(&body);
        body.extend_from_slice(&crc.to_le_bytes());
        let e = from_bytes(&body).unwrap_err();
        assert_eq!(classify(&e), Some(FailureClass::Malformed));
        // Unrelated errors don't classify.
        assert_eq!(classify(&anyhow::anyhow!("nope")), None);
    }

    #[test]
    fn escalated_from_roundtrips_and_stays_optional() {
        let (mut meta, _) = sample();
        meta.escalated_from = Some("drum6".into());
        let bytes = to_bytes(&meta, &[]);
        let (m2, _) = from_bytes(&bytes).unwrap();
        assert_eq!(m2.escalated_from.as_deref(), Some("drum6"));
        // Unset -> key absent from the JSON header entirely.
        let (plain, _) = sample();
        let bytes = to_bytes(&plain, &[]);
        let (m3, _) = from_bytes(&bytes).unwrap();
        assert_eq!(m3.escalated_from, None);
        assert!(!String::from_utf8_lossy(&bytes).contains("escalated_from"));
    }

    fn temp_store(label: &str) -> (Store, PathBuf) {
        let dir = std::env::temp_dir()
            .join(format!("axm-ckpt-{label}-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        (Store::new(&dir).unwrap(), dir)
    }

    fn save_epochs(store: &Store, epochs: &[u64]) {
        let (mut meta, tensors) = sample();
        let named: Vec<(String, &Tensor)> =
            tensors.iter().map(|(n, t)| (n.clone(), t)).collect();
        for &e in epochs {
            meta.epoch = e;
            store.save(&meta, &named).unwrap();
        }
    }

    #[test]
    fn retention_keeps_last_k_and_sweeps_stale_tmps() {
        let (store, dir) = temp_store("gc");
        save_epochs(&store, &[1, 2, 3, 4, 5]);
        // A stale tmp from a "dead" process (different pid suffix).
        let stale = dir.join("unit-epoch0009.ckpt.99999999.tmp");
        std::fs::write(&stale, b"partial").unwrap();
        // Our own in-flight tmp must survive.
        let mine = dir.join(format!("unit-epoch0009.ckpt.{}.tmp", std::process::id()));
        std::fs::write(&mine, b"partial").unwrap();
        let removed = store.gc_keep_last("unit", 3).unwrap();
        assert_eq!(removed, 3); // epochs 1, 2 + stale tmp
        assert_eq!(store.list_epochs("unit").unwrap(), vec![3, 4, 5]);
        assert!(!stale.exists());
        assert!(mine.exists());
        // keep == 0 means retain everything.
        assert_eq!(store.gc_keep_last("unit", 0).unwrap(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn latest_valid_scans_past_corruption() {
        let (store, dir) = temp_store("scan");
        save_epochs(&store, &[1, 2, 3]);
        // Corrupt the newest, truncate the next; epoch 1 stays good.
        let p3 = store.path_for("unit", 3);
        let mut b = std::fs::read(&p3).unwrap();
        let mid = b.len() / 2;
        b[mid] ^= 0xFF;
        std::fs::write(&p3, &b).unwrap();
        let p2 = store.path_for("unit", 2);
        let b = std::fs::read(&p2).unwrap();
        std::fs::write(&p2, &b[..10]).unwrap();
        let (epoch, meta, tensors) = store.latest_valid("unit").unwrap().unwrap();
        assert_eq!(epoch, 1);
        assert_eq!(meta.epoch, 1);
        assert_eq!(tensors.len(), 3);
        // All candidates bad -> Ok(None), not an error.
        let p1 = store.path_for("unit", 1);
        std::fs::write(&p1, b"junk").unwrap();
        assert!(store.latest_valid("unit").unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn injected_store_faults_fire_once() {
        let (store, dir) = temp_store("fault");
        let (meta, tensors) = sample();
        let named: Vec<(String, &Tensor)> =
            tensors.iter().map(|(n, t)| (n.clone(), t)).collect();
        // Torn write: save "succeeds" but the file is unreadable.
        store.inject_fault(Some(StoreFault::TearNextSave { keep: 64 }));
        store.save(&meta, &named).unwrap();
        let e = store.load("unit", 3).unwrap_err();
        assert_eq!(classify(&e), Some(FailureClass::CrcMismatch));
        // Failed save: classified Io error, tmp debris left behind.
        store.inject_fault(Some(StoreFault::FailNextSave));
        let e = store.save(&meta, &named).unwrap_err();
        assert_eq!(classify(&e), Some(FailureClass::Io));
        // One-shot: the next save is clean and readable again.
        store.save(&meta, &named).unwrap();
        assert!(store.load("unit", 3).is_ok());
        // Missing file classifies as Missing.
        let e = store.load("unit", 77).unwrap_err();
        assert_eq!(classify(&e), Some(FailureClass::Missing));
        std::fs::remove_dir_all(&dir).ok();
    }
}
