//! Checkpoint store: CRC-checked binary snapshots of the full training
//! state (params ++ BN state ++ optimizer momentum).
//!
//! The paper's procedures lean on checkpointing twice: Figure 3
//! ("download weights after certain epochs ... resume from that epoch")
//! and the Figure-4 hybrid switch-epoch search, which resumes an exact
//! tail from every candidate epoch of a single approximate run. The
//! format is self-describing so a checkpoint can be inspected and
//! restored without the engine.
//!
//! Layout (little endian):
//! ```text
//! magic "AXMCKPT1" | meta_len u32 | meta json bytes
//! repeat per tensor: name_len u32 | name | dtype u8 | rank u32 |
//!                    dims u64[rank] | payload u32[prod(dims)]
//! crc32 of everything above
//! ```

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::json::Value;
use crate::tensor::{DType, Tensor};

const MAGIC: &[u8; 8] = b"AXMCKPT1";

/// Checkpoint metadata (JSON header).
#[derive(Debug, Clone)]
pub struct Meta {
    pub preset: String,
    pub epoch: u64,
    pub step: u64,
    /// Sigma the run was training with when snapshotted (Gaussian
    /// surrogate only; 0 for exact and bit-accurate designs).
    pub sigma: f64,
    /// Canonical multiplier spec in force when snapshotted (`exact`,
    /// `gaussian:<sigma>`, `drum6`, ...) — sigma alone loses the
    /// multiplier's identity.
    pub mult: String,
    /// Free-form tag (e.g. "table2-case4").
    pub tag: String,
}

impl Meta {
    fn to_json(&self) -> Value {
        crate::json::object([
            ("preset", Value::from(self.preset.as_str())),
            ("epoch", Value::from(self.epoch as usize)),
            ("step", Value::from(self.step as usize)),
            ("sigma", Value::from(self.sigma)),
            ("mult", Value::from(self.mult.as_str())),
            ("tag", Value::from(self.tag.as_str())),
        ])
    }

    fn from_json(v: &Value) -> Result<Self> {
        let sigma = v.get("sigma")?.as_f64()?;
        // Pre-backend-split checkpoints have no `mult` key: their only
        // multiplier identity *was* the sigma, so reconstruct it.
        let mult = match v.opt("mult") {
            Some(m) => m.as_str()?.to_string(),
            None if sigma > 0.0 => format!("gaussian:{sigma}"),
            None => "exact".to_string(),
        };
        Ok(Meta {
            preset: v.get("preset")?.as_str()?.to_string(),
            epoch: v.get("epoch")?.as_i64()? as u64,
            step: v.get("step")?.as_i64()? as u64,
            sigma,
            mult,
            tag: v.get("tag")?.as_str()?.to_string(),
        })
    }
}

/// Serialize a checkpoint to bytes.
pub fn to_bytes(meta: &Meta, named: &[(String, &Tensor)]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    let meta_bytes = meta.to_json().to_string().into_bytes();
    out.extend_from_slice(&(meta_bytes.len() as u32).to_le_bytes());
    out.extend_from_slice(&meta_bytes);
    out.extend_from_slice(&(named.len() as u32).to_le_bytes());
    for (name, t) in named {
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        out.push(match t.dtype() {
            DType::F32 => 0,
            DType::I32 => 1,
            DType::U32 => 2,
        });
        out.extend_from_slice(&(t.shape().len() as u32).to_le_bytes());
        for &d in t.shape() {
            out.extend_from_slice(&(d as u64).to_le_bytes());
        }
        for &w in t.raw() {
            out.extend_from_slice(&w.to_le_bytes());
        }
    }
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Parse checkpoint bytes.
pub fn from_bytes(bytes: &[u8]) -> Result<(Meta, Vec<(String, Tensor)>)> {
    if bytes.len() < MAGIC.len() + 8 {
        bail!("checkpoint truncated ({} bytes)", bytes.len());
    }
    let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
    let stored = u32::from_le_bytes(crc_bytes.try_into().unwrap());
    let computed = crc32(body);
    if stored != computed {
        bail!("checkpoint CRC mismatch: stored {stored:#10x}, computed {computed:#10x}");
    }
    let mut r = Reader { b: body, pos: 0 };
    let magic = r.take(8)?;
    if magic != MAGIC {
        bail!("bad checkpoint magic");
    }
    let meta_len = r.u32()? as usize;
    let meta_bytes = r.take(meta_len)?;
    let meta = Meta::from_json(&Value::parse(std::str::from_utf8(meta_bytes)?)?)?;
    let count = r.u32()? as usize;
    let mut tensors = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = r.u32()? as usize;
        let name = std::str::from_utf8(r.take(name_len)?)?.to_string();
        let dtype = match r.u8()? {
            0 => DType::F32,
            1 => DType::I32,
            2 => DType::U32,
            d => bail!("bad dtype tag {d}"),
        };
        let rank = r.u32()? as usize;
        if rank > 8 {
            bail!("absurd rank {rank}");
        }
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(r.u64()? as usize);
        }
        let n: usize = dims.iter().product();
        let payload = r.take(n * 4)?;
        let words: Vec<u32> = payload
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let t = match dtype {
            DType::F32 => Tensor::from_f32(
                &dims,
                words.iter().map(|&w| f32::from_bits(w)).collect(),
            )?,
            DType::I32 => {
                Tensor::from_i32(&dims, words.iter().map(|&w| w as i32).collect())?
            }
            DType::U32 => Tensor::from_u32(&dims, words)?,
        };
        tensors.push((name, t));
    }
    if r.pos != body.len() {
        bail!("trailing bytes in checkpoint");
    }
    Ok((meta, tensors))
}

/// Bounds-checked little-endian cursor over checkpoint bytes.
struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!("checkpoint truncated at offset {}", self.pos);
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// Disk-backed checkpoint store with epoch-indexed naming.
pub struct Store {
    dir: PathBuf,
}

impl Store {
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        Ok(Store { dir })
    }

    pub fn path_for(&self, tag: &str, epoch: u64) -> PathBuf {
        self.dir.join(format!("{tag}-epoch{epoch:04}.ckpt"))
    }

    /// Write atomically (tmp + rename).
    pub fn save(&self, meta: &Meta, named: &[(String, &Tensor)]) -> Result<PathBuf> {
        let path = self.path_for(&meta.tag, meta.epoch);
        let tmp = path.with_extension("ckpt.tmp");
        let bytes = to_bytes(meta, named);
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(&bytes)?;
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, &path)?;
        Ok(path)
    }

    pub fn load(&self, tag: &str, epoch: u64) -> Result<(Meta, Vec<(String, Tensor)>)> {
        self.load_path(&self.path_for(tag, epoch))
    }

    pub fn load_path(&self, path: &Path) -> Result<(Meta, Vec<(String, Tensor)>)> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?
            .read_to_end(&mut bytes)?;
        from_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn exists(&self, tag: &str, epoch: u64) -> bool {
        self.path_for(tag, epoch).exists()
    }
}

/// CRC-32 (IEEE 802.3), table-driven.
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut crc = !0u32;
    for &b in bytes {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> (Meta, Vec<(String, Tensor)>) {
        (
            Meta {
                preset: "tiny".into(),
                epoch: 3,
                step: 99,
                sigma: 0.045,
                mult: "gaussian:0.045".into(),
                tag: "unit".into(),
            },
            vec![
                ("w".into(), Tensor::from_f32(&[2, 2], vec![1., -2., 3., 0.5]).unwrap()),
                ("y".into(), Tensor::from_i32(&[3], vec![1, -1, 7]).unwrap()),
                ("s".into(), Tensor::scalar_u32(42)),
            ],
        )
    }

    #[test]
    fn roundtrip() {
        let (meta, tensors) = sample();
        let named: Vec<(String, &Tensor)> =
            tensors.iter().map(|(n, t)| (n.clone(), t)).collect();
        let bytes = to_bytes(&meta, &named);
        let (m2, t2) = from_bytes(&bytes).unwrap();
        assert_eq!(m2.preset, "tiny");
        assert_eq!(m2.epoch, 3);
        assert_eq!(m2.sigma, 0.045);
        assert_eq!(m2.mult, "gaussian:0.045");
        assert_eq!(t2.len(), 3);
        assert_eq!(t2[0].1.as_f32().unwrap(), vec![1., -2., 3., 0.5]);
        assert_eq!(t2[1].1.as_i32().unwrap(), vec![1, -1, 7]);
    }

    /// A hand-built checkpoint whose JSON header predates the `mult`
    /// key (the old format) must still load, deriving the multiplier
    /// identity from sigma.
    #[test]
    fn legacy_checkpoint_without_mult_loads() {
        let build = |meta_json: &str| -> Vec<u8> {
            let mut bytes = Vec::new();
            bytes.extend_from_slice(MAGIC);
            bytes.extend_from_slice(&(meta_json.len() as u32).to_le_bytes());
            bytes.extend_from_slice(meta_json.as_bytes());
            bytes.extend_from_slice(&0u32.to_le_bytes()); // zero tensors
            let crc = crc32(&bytes);
            bytes.extend_from_slice(&crc.to_le_bytes());
            bytes
        };
        let legacy = build(
            r#"{"epoch":2,"preset":"tiny","sigma":0.12,"step":7,"tag":"old"}"#,
        );
        let (meta, tensors) = from_bytes(&legacy).unwrap();
        assert_eq!(meta.epoch, 2);
        assert_eq!(meta.mult, "gaussian:0.12");
        assert!(tensors.is_empty());
        let exact = build(
            r#"{"epoch":1,"preset":"tiny","sigma":0.0,"step":3,"tag":"old"}"#,
        );
        let (meta, _) = from_bytes(&exact).unwrap();
        assert_eq!(meta.mult, "exact");
    }

    #[test]
    fn corruption_detected() {
        let (meta, tensors) = sample();
        let named: Vec<(String, &Tensor)> =
            tensors.iter().map(|(n, t)| (n.clone(), t)).collect();
        let mut bytes = to_bytes(&meta, &named);
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        assert!(from_bytes(&bytes).is_err());
    }

    #[test]
    fn truncation_detected() {
        let (meta, tensors) = sample();
        let named: Vec<(String, &Tensor)> =
            tensors.iter().map(|(n, t)| (n.clone(), t)).collect();
        let bytes = to_bytes(&meta, &named);
        assert!(from_bytes(&bytes[..bytes.len() - 1]).is_err());
        assert!(from_bytes(&bytes[..10]).is_err());
    }

    #[test]
    fn store_roundtrip() {
        let dir = std::env::temp_dir().join(format!("axm-ckpt-{}", std::process::id()));
        let store = Store::new(&dir).unwrap();
        let (meta, tensors) = sample();
        let named: Vec<(String, &Tensor)> =
            tensors.iter().map(|(n, t)| (n.clone(), t)).collect();
        store.save(&meta, &named).unwrap();
        assert!(store.exists("unit", 3));
        let (m2, t2) = store.load("unit", 3).unwrap();
        assert_eq!(m2.step, 99);
        assert_eq!(t2.len(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn crc_known_answer() {
        // CRC32("123456789") = 0xCBF43926 (classic check value).
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }
}
