//! Resident inference sessions: load once, decompose once, serve many.
//!
//! [`SpecSession`] owns everything one multiplier spec needs to answer
//! requests: a [`NativeBackend`] bound to that spec, the (possibly
//! error-injected) f32 weights, the BN running state, and the weight
//! planes decomposed **once** at construction
//! ([`NativeBackend::pack_infer_weights`] — for `lut`/`slut` specs the
//! product tables were built once inside the backend's design, and for
//! signed specs the signed-mantissa planes are derived here too). Per
//! request batch, the only work left is the activation prepare and the
//! GEMM chain.
//!
//! [`InferenceSession`] is the multi-tenant registry: one checkpoint's
//! weights shared across a *bounded* set of spec sessions, keyed by
//! canonical spec string in a `BTreeMap` (deterministic iteration —
//! detlint D1). Two tenants asking for the same canonical spec share
//! one resident plane set; distinct specs get their own entry; specs
//! past the bound are a typed construction error, not an unbounded
//! cache.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::checkpoint::Store;
use crate::mult::{MultSpec, PreparedMatrix};
use crate::runtime::{Backend, NativeBackend};
use crate::tensor::Tensor;

/// One spec's resident state: weights decomposed once, served many.
pub struct SpecSession {
    spec: MultSpec,
    backend: NativeBackend,
    /// Inference weights (Gaussian specs: error field already applied).
    params: Vec<Vec<f32>>,
    /// BN running statistics.
    state: Vec<Vec<f32>>,
    /// Weight planes, decomposed once at construction.
    packed: Vec<PreparedMatrix>,
    /// Number of `PreparedMatrix` decompositions performed for this
    /// session — exactly one per GEMM layer, pinned by test.
    prepare_calls: u64,
}

impl SpecSession {
    fn build(
        preset: &str,
        spec: MultSpec,
        params: &[Vec<f32>],
        state: &[Vec<f32>],
        seed_err: u32,
    ) -> Result<Self> {
        let backend = NativeBackend::new(preset, spec.clone())
            .with_context(|| format!("building serve backend for {}", spec.canonical()))?;
        let params = backend.infer_params(params, seed_err);
        let packed = backend
            .pack_infer_weights(&params)
            .with_context(|| format!("decomposing weights for {}", spec.canonical()))?;
        let prepare_calls = backend.n_gemm_layers() as u64;
        Ok(SpecSession {
            spec,
            backend,
            params,
            state: state.to_vec(),
            packed,
            prepare_calls,
        })
    }

    pub fn spec(&self) -> &MultSpec {
        &self.spec
    }

    /// Logits for `n` examples under this spec's resident planes.
    pub fn infer(&self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        self.backend
            .infer_logits(&self.params, &self.state, &self.packed, x, n)
    }

    /// Decompositions performed since construction (constant after
    /// build: the serving path never re-packs weights).
    pub fn prepare_calls(&self) -> u64 {
        self.prepare_calls
    }
}

/// Multi-tenant resident inference over one checkpoint.
pub struct InferenceSession {
    preset: String,
    /// Flat elements of one input example (`hw * hw * ch`).
    input_elems: usize,
    num_classes: usize,
    /// Source checkpoint epoch, `None` for fresh-init sessions.
    checkpoint_epoch: Option<u64>,
    /// Canonical spec → resident session, deterministic iteration.
    sessions: BTreeMap<String, SpecSession>,
}

impl InferenceSession {
    /// Load the latest valid checkpoint under `tag` from `dir` (the
    /// verified-load path: corrupt snapshots are scanned past, not
    /// served) and build one resident session per distinct spec.
    pub fn from_store(
        dir: impl AsRef<Path>,
        tag: &str,
        specs: &[MultSpec],
        max_specs: usize,
        seed_err: u32,
    ) -> Result<Self> {
        let store = Store::new(dir.as_ref())?;
        let Some((epoch, meta, named)) = store
            .latest_valid(tag)
            .with_context(|| format!("scanning checkpoints for tag {tag:?}"))?
        else {
            bail!(
                "no valid checkpoint for tag {tag:?} in {}",
                dir.as_ref().display()
            );
        };
        let (params, state) = split_named(&meta.preset, named)?;
        let mut s = Self::from_parts(&meta.preset, &params, &state, specs, max_specs, seed_err)?;
        s.checkpoint_epoch = Some(epoch);
        Ok(s)
    }

    /// Session at freshly initialized weights — cold-start serving and
    /// smoke tests (no checkpoint required).
    pub fn from_fresh(
        preset: &str,
        seed: u32,
        specs: &[MultSpec],
        max_specs: usize,
        seed_err: u32,
    ) -> Result<Self> {
        let init_backend = NativeBackend::new(preset, MultSpec::Exact)?;
        let model = init_backend.model();
        let n_params = model.params.len();
        let n_state = model.state.len();
        let tensors = init_backend.init(seed)?;
        let params = to_vecs(
            tensors
                .get(..n_params)
                .context("init returned too few tensors for params")?,
        )?;
        let state = to_vecs(
            tensors
                .get(n_params..n_params + n_state)
                .context("init returned too few tensors for state")?,
        )?;
        Self::from_parts(preset, &params, &state, specs, max_specs, seed_err)
    }

    /// Core constructor over already-split f32 weights.
    fn from_parts(
        preset: &str,
        params: &[Vec<f32>],
        state: &[Vec<f32>],
        specs: &[MultSpec],
        max_specs: usize,
        seed_err: u32,
    ) -> Result<Self> {
        if specs.is_empty() {
            bail!("serve needs at least one multiplier spec");
        }
        let probe = NativeBackend::new(preset, MultSpec::Exact)?;
        let model = probe.model();
        let input_elems = model.input_hw * model.input_hw * model.in_ch;
        let num_classes = model.num_classes;

        let mut sessions: BTreeMap<String, SpecSession> = BTreeMap::new();
        for spec in specs {
            let key = spec.canonical();
            if sessions.contains_key(&key) {
                // Same canonical spec twice: tenants share the one
                // resident plane set — no second decomposition.
                continue;
            }
            if sessions.len() >= max_specs {
                bail!(
                    "spec registry bounded at {max_specs}: cannot add {key} \
                     (resident: {})",
                    sessions.keys().cloned().collect::<Vec<_>>().join(", ")
                );
            }
            let sess = SpecSession::build(preset, spec.clone(), params, state, seed_err)?;
            sessions.insert(key, sess);
        }
        Ok(InferenceSession {
            preset: preset.to_string(),
            input_elems,
            num_classes,
            checkpoint_epoch: None,
            sessions,
        })
    }

    pub fn preset(&self) -> &str {
        &self.preset
    }

    /// Flat elements of one input example.
    pub fn input_elems(&self) -> usize {
        self.input_elems
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// Epoch of the restored checkpoint (`None` = fresh init).
    pub fn checkpoint_epoch(&self) -> Option<u64> {
        self.checkpoint_epoch
    }

    /// Canonical specs with resident sessions, in registry order.
    pub fn specs(&self) -> Vec<String> {
        self.sessions.keys().cloned().collect()
    }

    pub fn has_spec(&self, canonical: &str) -> bool {
        self.sessions.contains_key(canonical)
    }

    /// Logits for `n` examples under `canonical`'s resident planes.
    /// Unknown specs are a typed error (the server maps it to a
    /// `bad-input` rejection at admission, so this is a backstop).
    pub fn infer(&self, canonical: &str, x: &[f32], n: usize) -> Result<Vec<f32>> {
        let Some(sess) = self.sessions.get(canonical) else {
            bail!(
                "no resident session for spec {canonical:?} (resident: {})",
                self.specs().join(", ")
            );
        };
        sess.infer(x, n)
    }

    /// Total weight decompositions across all resident sessions —
    /// exactly `n_gemm_layers x n_distinct_specs`, and constant over
    /// the session's lifetime (pinned by `tests/serve_batching.rs`).
    pub fn prepare_calls(&self) -> u64 {
        let mut total = 0u64;
        for s in self.sessions.values() {
            total += s.prepare_calls();
        }
        total
    }
}

/// Split a checkpoint's named tensors into f32 params and state in
/// manifest order, ignoring the optimizer tail. Missing or misshapen
/// tensors are typed errors.
fn split_named(
    preset: &str,
    named: Vec<(String, Tensor)>,
) -> Result<(Vec<Vec<f32>>, Vec<Vec<f32>>)> {
    let probe = NativeBackend::new(preset, MultSpec::Exact)?;
    let model = probe.model();
    let by_name: BTreeMap<String, Tensor> = named.into_iter().collect();
    let lookup = |prefix: &str, name: &str, shape: &[usize]| -> Result<Vec<f32>> {
        let full = format!("{prefix}:{name}");
        let Some(t) = by_name.get(&full) else {
            bail!("checkpoint is missing tensor {full:?} for preset {preset}");
        };
        if t.shape() != shape {
            bail!(
                "checkpoint tensor {full:?} shape {:?} != manifest {:?}",
                t.shape(),
                shape
            );
        }
        t.as_f32()
    };
    let mut params = Vec::with_capacity(model.params.len());
    for spec in &model.params {
        params.push(lookup("param", &spec.name, &spec.shape)?);
    }
    let mut state = Vec::with_capacity(model.state.len());
    for spec in &model.state {
        state.push(lookup("state", &spec.name, &spec.shape)?);
    }
    Ok((params, state))
}

/// Extract f32 buffers from a tensor slice.
fn to_vecs(tensors: &[Tensor]) -> Result<Vec<Vec<f32>>> {
    tensors.iter().map(|t| t.as_f32()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs(list: &[&str]) -> Vec<MultSpec> {
        list.iter().map(|s| MultSpec::parse(s).unwrap()).collect()
    }

    #[test]
    fn fresh_session_serves_all_registered_specs() {
        let s = InferenceSession::from_fresh(
            "micro",
            7,
            &specs(&["exact", "drum6", "sdrum6"]),
            8,
            11,
        )
        .unwrap();
        assert_eq!(s.specs(), ["drum6", "exact", "sdrum6"]);
        let x = vec![0.1f32; s.input_elems() * 2];
        for spec in s.specs() {
            let logits = s.infer(&spec, &x, 2).unwrap();
            assert_eq!(logits.len(), 2 * s.num_classes());
            assert!(logits.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn duplicate_canonical_specs_share_one_session() {
        let s = InferenceSession::from_fresh(
            "micro",
            7,
            &specs(&["drum6", "drum6", "exact"]),
            8,
            11,
        )
        .unwrap();
        assert_eq!(s.specs().len(), 2);
        // prepare_calls counts layers once per *distinct* spec.
        let probe = NativeBackend::new("micro", MultSpec::Exact).unwrap();
        assert_eq!(s.prepare_calls(), 2 * probe.n_gemm_layers() as u64);
    }

    #[test]
    fn registry_bound_is_a_typed_error() {
        let err = InferenceSession::from_fresh(
            "micro",
            7,
            &specs(&["exact", "drum6", "drum4"]),
            2,
            11,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("bounded at 2"), "{err:#}");
    }

    #[test]
    fn unknown_spec_is_a_typed_error() {
        let s =
            InferenceSession::from_fresh("micro", 7, &specs(&["exact"]), 4, 11).unwrap();
        let x = vec![0.0; s.input_elems()];
        assert!(s.infer("drum6", &x, 1).is_err());
    }

    #[test]
    fn bad_input_length_is_a_typed_error() {
        let s =
            InferenceSession::from_fresh("micro", 7, &specs(&["exact"]), 4, 11).unwrap();
        assert!(s.infer("exact", &[0.0, 1.0], 1).is_err());
    }

    #[test]
    fn gaussian_spec_differs_from_exact_but_is_reproducible() {
        let build = || {
            InferenceSession::from_fresh(
                "micro",
                7,
                &specs(&["exact", "gaussian:0.08"]),
                4,
                11,
            )
            .unwrap()
        };
        let s1 = build();
        let s2 = build();
        let n = 2;
        let x: Vec<f32> = (0..n * s1.input_elems())
            .map(|i| (i as f32) * 0.01 - 0.3)
            .collect();
        let exact = s1.infer("exact", &x, n).unwrap();
        let g1 = s1.infer("gaussian:0.08", &x, n).unwrap();
        let g2 = s2.infer("gaussian:0.08", &x, n).unwrap();
        // Same seed_err → bit-identical injected weights across builds.
        assert_eq!(g1, g2);
        // And the injected field actually moved the logits.
        assert_ne!(exact, g1);
    }
}
