//! Time source for the serving path.
//!
//! Every batching *decision* is a function of `u64` microsecond
//! timestamps — never of a wall-clock read taken inside the decision
//! math (detlint D2). The real clock exists only behind the [`Clock`]
//! trait as [`SystemClock`]; tests and `serve-bench` replay drive the
//! same batcher on a [`VirtualClock`], which is how identical arrival
//! traces produce bit-identical batch compositions on any machine at
//! any thread count.

use std::cell::Cell;

/// Monotonic microsecond time source for admission stamps and batch
/// flush decisions.
pub trait Clock {
    /// Microseconds since this clock's origin. Must be monotonic
    /// non-decreasing.
    fn now_us(&self) -> u64;
}

/// Deterministic test/replay clock: time moves only when the driver
/// advances it.
#[derive(Debug, Default)]
pub struct VirtualClock {
    now: Cell<u64>,
}

impl VirtualClock {
    pub fn new(start_us: u64) -> Self {
        VirtualClock { now: Cell::new(start_us) }
    }

    /// Jump to an absolute timestamp. Never moves backwards — replay
    /// event loops may compute the same event time twice.
    pub fn advance_to(&self, t_us: u64) {
        if t_us > self.now.get() {
            self.now.set(t_us);
        }
    }
}

impl Clock for VirtualClock {
    fn now_us(&self) -> u64 {
        self.now.get()
    }
}

/// The real clock: monotonic microseconds since construction. Only the
/// live `serve` CLI uses this; nothing downstream of [`Clock::now_us`]
/// can tell it apart from a replayed [`VirtualClock`].
pub struct SystemClock {
    // detlint: allow(D2) -- the Clock trait boundary: the one sanctioned wall-clock source for live serving; decision math sees only u64 stamps
    origin: std::time::Instant,
}

impl SystemClock {
    pub fn new() -> Self {
        // detlint: allow(D2) -- capturing the live clock origin; replay paths never construct a SystemClock
        SystemClock { origin: std::time::Instant::now() }
    }
}

impl Default for SystemClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for SystemClock {
    fn now_us(&self) -> u64 {
        self.origin.elapsed().as_micros() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_is_settable_and_monotonic() {
        let c = VirtualClock::new(10);
        assert_eq!(c.now_us(), 10);
        c.advance_to(100);
        assert_eq!(c.now_us(), 100);
        // Backwards jumps are ignored.
        c.advance_to(50);
        assert_eq!(c.now_us(), 100);
    }

    #[test]
    fn system_clock_is_nondecreasing() {
        let c = SystemClock::new();
        let a = c.now_us();
        let b = c.now_us();
        assert!(b >= a);
    }
}
