//! Bounded, spec-laned request queue.
//!
//! Admitted requests wait here until the batcher flushes them. Lanes
//! are keyed by *canonical* multiplier spec in a `BTreeMap` — never a
//! hash map (detlint D1) — so the batcher visits lanes in one fixed
//! order and batch compositions are a pure function of the arrival
//! trace. Within a lane, requests are FIFO by admission sequence.
//!
//! The queue is bounded across all lanes: admission past capacity is a
//! typed [`EnqueueError::Full`], the backpressure signal the server
//! turns into a `queue-full` rejection.

use std::collections::{BTreeMap, VecDeque};

/// One admitted request waiting for a batch slot.
#[derive(Debug, Clone, PartialEq)]
pub struct Pending {
    pub id: u64,
    pub tenant: String,
    /// Admission timestamp (µs, server clock).
    pub arrival_us: u64,
    /// Absolute completion deadline (µs, server clock): admission
    /// time + the request's relative budget.
    pub deadline_us: u64,
    /// One flat `[hw, hw, ch]` example.
    pub input: Vec<f32>,
    /// Admission sequence number — the FIFO total order.
    pub seq: u64,
}

/// Typed admission failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EnqueueError {
    /// The queue holds `capacity` requests across all lanes.
    Full { capacity: usize },
}

impl std::fmt::Display for EnqueueError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EnqueueError::Full { capacity } => {
                write!(f, "queue full at capacity {capacity}")
            }
        }
    }
}

impl std::error::Error for EnqueueError {}

/// Snapshot of one lane, the batcher's trigger inputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaneSummary {
    pub len: usize,
    /// Earliest absolute deadline in the lane.
    pub deadline_min_us: u64,
    /// Arrival time of the oldest (front) request.
    pub oldest_arrival_us: u64,
}

/// Bounded multi-lane FIFO keyed by canonical spec.
#[derive(Debug, Default)]
pub struct ServeQueue {
    lanes: BTreeMap<String, VecDeque<Pending>>,
    len: usize,
    capacity: usize,
    next_seq: u64,
}

impl ServeQueue {
    pub fn new(capacity: usize) -> Self {
        ServeQueue { lanes: BTreeMap::new(), len: 0, capacity, next_seq: 0 }
    }

    /// Total queued requests across all lanes.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Admit one request into `spec`'s lane; assigns and returns its
    /// admission sequence number.
    pub fn push(&mut self, spec: &str, mut p: Pending) -> Result<u64, EnqueueError> {
        if self.len >= self.capacity {
            return Err(EnqueueError::Full { capacity: self.capacity });
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        p.seq = seq;
        self.lanes.entry(spec.to_string()).or_default().push_back(p);
        self.len += 1;
        Ok(seq)
    }

    /// Lane keys in canonical (BTreeMap) order — the batcher's fixed
    /// visit order. Empty lanes are skipped.
    pub fn specs(&self) -> Vec<String> {
        self.lanes
            .iter()
            .filter(|(_, l)| !l.is_empty())
            .map(|(k, _)| k.clone())
            .collect()
    }

    /// Trigger inputs for one lane; `None` when empty or absent.
    pub fn lane_summary(&self, spec: &str) -> Option<LaneSummary> {
        let lane = self.lanes.get(spec)?;
        let front = lane.front()?;
        let deadline_min_us = lane.iter().map(|p| p.deadline_us).min()?;
        Some(LaneSummary {
            len: lane.len(),
            deadline_min_us,
            oldest_arrival_us: front.arrival_us,
        })
    }

    /// Remove every request in `spec`'s lane whose absolute deadline is
    /// strictly below `cutoff_us` (it cannot complete by its deadline
    /// even if flushed right now). Relative order of survivors is
    /// preserved; the removed requests are returned for typed
    /// `deadline-missed` rejection.
    pub fn drain_expired(&mut self, spec: &str, cutoff_us: u64) -> Vec<Pending> {
        let Some(lane) = self.lanes.get_mut(spec) else {
            return Vec::new();
        };
        let mut expired = Vec::new();
        let mut kept = VecDeque::with_capacity(lane.len());
        for p in lane.drain(..) {
            if p.deadline_us < cutoff_us {
                expired.push(p);
            } else {
                kept.push_back(p);
            }
        }
        *lane = kept;
        self.len -= expired.len();
        expired
    }

    /// Dequeue up to `k` requests from the front of `spec`'s lane, in
    /// FIFO order — one GEMM batch's worth.
    pub fn take_front(&mut self, spec: &str, k: usize) -> Vec<Pending> {
        let Some(lane) = self.lanes.get_mut(spec) else {
            return Vec::new();
        };
        let n = k.min(lane.len());
        let taken: Vec<Pending> = lane.drain(..n).collect();
        self.len -= taken.len();
        taken
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(id: u64, arrival: u64, deadline: u64) -> Pending {
        Pending {
            id,
            tenant: "t".into(),
            arrival_us: arrival,
            deadline_us: deadline,
            input: vec![0.0],
            seq: 0,
        }
    }

    #[test]
    fn capacity_is_global_and_typed() {
        let mut q = ServeQueue::new(2);
        q.push("a", p(1, 0, 10)).unwrap();
        q.push("b", p(2, 0, 10)).unwrap();
        assert_eq!(
            q.push("a", p(3, 0, 10)),
            Err(EnqueueError::Full { capacity: 2 })
        );
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn lanes_iterate_in_canonical_order() {
        let mut q = ServeQueue::new(10);
        q.push("sdrum6", p(1, 0, 10)).unwrap();
        q.push("booth8", p(2, 0, 10)).unwrap();
        q.push("exact", p(3, 0, 10)).unwrap();
        assert_eq!(q.specs(), ["booth8", "exact", "sdrum6"]);
    }

    #[test]
    fn seq_is_admission_order_across_lanes() {
        let mut q = ServeQueue::new(10);
        let s1 = q.push("b", p(1, 0, 10)).unwrap();
        let s2 = q.push("a", p(2, 0, 10)).unwrap();
        assert!(s2 > s1);
    }

    #[test]
    fn drain_expired_preserves_survivor_order() {
        let mut q = ServeQueue::new(10);
        q.push("a", p(1, 0, 100)).unwrap();
        q.push("a", p(2, 0, 5)).unwrap();
        q.push("a", p(3, 0, 200)).unwrap();
        let gone = q.drain_expired("a", 50);
        assert_eq!(gone.iter().map(|p| p.id).collect::<Vec<_>>(), [2]);
        assert_eq!(q.len(), 2);
        let taken = q.take_front("a", 10);
        assert_eq!(taken.iter().map(|p| p.id).collect::<Vec<_>>(), [1, 3]);
        assert!(q.is_empty());
    }

    #[test]
    fn take_front_caps_at_k() {
        let mut q = ServeQueue::new(10);
        for i in 0..5 {
            q.push("a", p(i, 0, 10)).unwrap();
        }
        assert_eq!(q.take_front("a", 3).len(), 3);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn summary_reports_min_deadline_not_front_deadline() {
        let mut q = ServeQueue::new(10);
        q.push("a", p(1, 7, 500)).unwrap();
        q.push("a", p(2, 9, 90)).unwrap();
        let s = q.lane_summary("a").unwrap();
        assert_eq!(s.len, 2);
        assert_eq!(s.deadline_min_us, 90);
        assert_eq!(s.oldest_arrival_us, 7);
    }
}
