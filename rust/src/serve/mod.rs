//! Serve mode: resident multi-tenant inference with deadline-aware
//! dynamic batching.
//!
//! The subsystem turns a trained checkpoint into a long-lived
//! inference service:
//!
//! - [`session::InferenceSession`] loads a checkpoint **once** (via
//!   the verified `checkpoint::Store::latest_valid` path), runs the
//!   one-time weight-plane decomposition per multiplier spec, and
//!   keeps the prepared planes resident. Distinct tenant
//!   [`crate::mult::MultSpec`]s get their own entries in a bounded,
//!   deterministically-iterated registry; tenants sharing a canonical
//!   spec share one plane set.
//! - [`queue::ServeQueue`] is the bounded admission queue, one FIFO
//!   lane per canonical spec, with typed overflow instead of panics.
//! - [`batcher::Batcher`] coalesces queued requests into GEMM-shaped
//!   batches under three triggers (deadline-imminent > batch-full >
//!   window-elapsed) using a serial busy-horizon service model — all
//!   decision math on integer microseconds, never the wall clock.
//! - [`codec`] is the wire layer: typed request / response / rejection
//!   structs over the in-tree `json` value model.
//! - [`driver::Server`] glues admission, batching, execution and
//!   latency accounting together; [`driver::replay`] runs a timed
//!   trace on a [`clock::VirtualClock`] for bit-identical benchmarks.
//!
//! Real time enters exactly once, through [`clock::SystemClock`]
//! behind the [`clock::Clock`] trait; everything downstream of
//! `now_us()` is deterministic in the timestamps it is handed.

pub mod batcher;
pub mod clock;
pub mod codec;
pub mod driver;
pub mod queue;
pub mod session;

pub use batcher::{Batch, BatchPolicy, Batcher, FlushTrigger};
pub use clock::{Clock, SystemClock, VirtualClock};
pub use codec::{InferReject, InferRequest, InferResponse, RejectReason};
pub use driver::{replay, synth_trace, BatchRecord, PollResult, ReplaySummary, Server, ServeStats, TimedRequest, TraceSpec};
pub use queue::{EnqueueError, LaneSummary, Pending, ServeQueue};
pub use session::InferenceSession;
