//! The serve loop: admission, batching, execution, accounting.
//!
//! [`Server`] glues the pieces together: requests are validated and
//! stamped at admission ([`Server::submit`]), wait in the bounded
//! [`ServeQueue`], and are flushed by the [`Batcher`] into
//! single-spec GEMM batches executed on the resident
//! [`InferenceSession`]. Completion times and latencies come from the
//! deterministic service model (`start + service_estimate_us`), so a
//! replayed arrival trace produces bit-identical responses, batch
//! compositions, rejection sets and latency percentiles on every run
//! and at every thread count.
//!
//! [`replay`] is the deterministic driver: it walks a timed trace on a
//! [`VirtualClock`], alternating arrivals with due batcher events.
//! [`synth_trace`] builds such traces from `rng::counter_split`
//! streams — no wall clock anywhere (detlint D2).

use std::collections::BTreeMap;

use crate::benchkit::hist::LatencyHistogram;
use crate::config::ServeConfig;
use crate::rng::threefry::{counter_normal, counter_split};

use super::batcher::{Batcher, BatchPolicy, FlushTrigger};
use super::clock::{Clock, VirtualClock};
use super::codec::{InferReject, InferRequest, InferResponse, RejectReason};
use super::queue::{Pending, ServeQueue};
use super::session::InferenceSession;

/// Threefry domain tags for trace synthesis (disjoint from training's
/// init/dropout/error streams by construction: they only feed the
/// bench driver).
const TRACE_GAP_STREAM: u32 = 0x5345_4701; // "SEG" + 1
const TRACE_SPEC_STREAM: u32 = 0x5345_4702;
const TRACE_INPUT_STREAM: u32 = 0x5345_4703;

/// One executed batch, for the deterministic replay digest.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchRecord {
    pub spec: String,
    pub trigger: &'static str,
    pub flush_us: u64,
    pub complete_us: u64,
    pub ids: Vec<u64>,
}

/// Serving counters + per-spec latency histograms.
#[derive(Default)]
pub struct ServeStats {
    pub submitted: u64,
    pub completed: u64,
    pub rejected_queue: u64,
    pub rejected_deadline: u64,
    pub rejected_bad_input: u64,
    pub batches: u64,
    /// Latency histogram across all specs.
    pub latency: LatencyHistogram,
    /// Per-spec latency histograms, canonical order.
    pub latency_by_spec: BTreeMap<String, LatencyHistogram>,
}

impl ServeStats {
    fn record_latency(&mut self, spec: &str, us: u64) {
        self.latency.record(us);
        self.latency_by_spec.entry(spec.to_string()).or_default().record(us);
    }
}

/// Output of one [`Server::poll`].
#[derive(Debug, Default)]
pub struct PollResult {
    pub responses: Vec<InferResponse>,
    pub rejects: Vec<InferReject>,
}

/// Resident inference server: session + queue + batcher + accounting.
pub struct Server {
    session: InferenceSession,
    queue: ServeQueue,
    batcher: Batcher,
    /// Default canonical spec for requests that omit `mult`.
    default_spec: String,
    /// Modeled server-busy horizon (µs).
    busy_until_us: u64,
    stats: ServeStats,
    batch_log: Vec<BatchRecord>,
}

impl Server {
    /// Build a server over a resident session. The default spec for
    /// requests that omit `mult` is the registry's first (canonical
    /// order) spec.
    pub fn new(session: InferenceSession, cfg: &ServeConfig) -> anyhow::Result<Self> {
        cfg.validate()?;
        let default_spec = session
            .specs()
            .into_iter()
            .next()
            .ok_or_else(|| anyhow::anyhow!("session has no resident specs"))?;
        Ok(Server {
            session,
            queue: ServeQueue::new(cfg.queue_capacity),
            batcher: Batcher::new(BatchPolicy {
                max_batch: cfg.max_batch,
                batch_window_us: cfg.batch_window_us,
                service_estimate_us: cfg.service_estimate_us,
            }),
            default_spec,
            busy_until_us: 0,
            stats: ServeStats::default(),
            batch_log: Vec::new(),
        })
    }

    pub fn session(&self) -> &InferenceSession {
        &self.session
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Every executed batch in flush order — the replay digest.
    pub fn batch_log(&self) -> &[BatchRecord] {
        &self.batch_log
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Earliest future batcher event, for virtual drivers.
    pub fn next_event_us(&self, now_us: u64) -> Option<u64> {
        self.batcher.next_event_us(&self.queue, now_us)
    }

    /// Admit one request at `now_us`. Invalid requests and queue
    /// overflow return a typed rejection instead of queueing.
    pub fn submit(&mut self, req: InferRequest, now_us: u64) -> Result<u64, InferReject> {
        self.stats.submitted += 1;
        let (rid, rtenant) = (req.id, req.tenant.clone());
        let reject = move |reason: RejectReason, detail: String| InferReject {
            id: rid,
            tenant: rtenant.clone(),
            reason,
            detail,
        };
        let spec = match &req.mult {
            Some(s) => match crate::mult::MultSpec::parse(s) {
                Ok(m) => m.canonical(),
                Err(e) => {
                    self.stats.rejected_bad_input += 1;
                    return Err(reject(RejectReason::BadInput, format!("bad mult spec: {e:#}")));
                }
            },
            None => self.default_spec.clone(),
        };
        if !self.session.has_spec(&spec) {
            self.stats.rejected_bad_input += 1;
            return Err(reject(
                RejectReason::BadInput,
                format!(
                    "spec {spec:?} has no resident session (resident: {})",
                    self.session.specs().join(", ")
                ),
            ));
        }
        if req.input.len() != self.session.input_elems() {
            self.stats.rejected_bad_input += 1;
            return Err(reject(
                RejectReason::BadInput,
                format!(
                    "input has {} elements, expected {}",
                    req.input.len(),
                    self.session.input_elems()
                ),
            ));
        }
        if req.deadline_us == 0 {
            self.stats.rejected_bad_input += 1;
            return Err(reject(
                RejectReason::BadInput,
                "deadline_us must be >= 1".to_string(),
            ));
        }
        let pending = Pending {
            id: req.id,
            tenant: req.tenant.clone(),
            arrival_us: now_us,
            deadline_us: now_us.saturating_add(req.deadline_us),
            input: req.input,
            seq: 0,
        };
        match self.queue.push(&spec, pending) {
            Ok(seq) => Ok(seq),
            Err(e) => {
                self.stats.rejected_queue += 1;
                Err(reject(RejectReason::QueueFull, e.to_string()))
            }
        }
    }

    /// Run the batcher at `now_us` and execute every flushed batch.
    pub fn poll(&mut self, now_us: u64) -> anyhow::Result<PollResult> {
        let outcome = self.batcher.poll(&mut self.queue, now_us, self.busy_until_us);
        self.busy_until_us = self.busy_until_us.max(outcome.busy_until_us);
        let mut result = PollResult::default();
        for p in outcome.expired {
            self.stats.rejected_deadline += 1;
            result.rejects.push(InferReject {
                id: p.id,
                tenant: p.tenant,
                reason: RejectReason::DeadlineMissed,
                detail: format!(
                    "deadline {}us unmeetable at decision time {now_us}us",
                    p.deadline_us
                ),
            });
        }
        for batch in outcome.batches {
            let n = batch.requests.len();
            let mut x = Vec::with_capacity(n * self.session.input_elems());
            for r in &batch.requests {
                x.extend_from_slice(&r.input);
            }
            let logits = self.session.infer(&batch.spec, &x, n)?;
            let classes = self.session.num_classes();
            self.stats.batches += 1;
            self.batch_log.push(BatchRecord {
                spec: batch.spec.clone(),
                trigger: batch.trigger.name(),
                flush_us: batch.flush_us,
                complete_us: batch.complete_us,
                ids: batch.requests.iter().map(|r| r.id).collect(),
            });
            for (r, row) in batch.requests.iter().zip(logits.chunks(classes)) {
                let latency_us = batch.complete_us.saturating_sub(r.arrival_us);
                self.stats.completed += 1;
                self.stats.record_latency(&batch.spec, latency_us);
                result.responses.push(InferResponse {
                    id: r.id,
                    tenant: r.tenant.clone(),
                    mult: batch.spec.clone(),
                    class: argmax(row),
                    logits: row.to_vec(),
                    batch: n,
                    latency_us,
                });
            }
        }
        Ok(result)
    }

    /// Flush everything still queued (end-of-trace drain): advances a
    /// virtual cursor through remaining batcher events until the queue
    /// empties. Returns responses/rejects in event order.
    pub fn drain(&mut self, from_us: u64) -> anyhow::Result<PollResult> {
        let mut all = PollResult::default();
        let mut cursor = from_us;
        while let Some(event) = self.next_event_us(cursor) {
            cursor = cursor.max(event);
            let r = self.poll(cursor)?;
            all.responses.extend(r.responses);
            all.rejects.extend(r.rejects);
        }
        Ok(all)
    }
}

/// First-max argmax over one logits row (deterministic under ties and
/// total over NaN via `total_cmp`).
fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v.total_cmp(&best_v) == std::cmp::Ordering::Greater {
            best = i;
            best_v = v;
        }
    }
    best
}

/// One timed arrival in a replayable trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TimedRequest {
    pub arrival_us: u64,
    pub request: InferRequest,
}

/// Shape of a synthetic arrival trace.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    /// Threefry seed: same seed → same trace, bit-for-bit.
    pub seed: u64,
    pub requests: usize,
    /// Mean inter-arrival gap (µs); gaps are uniform on
    /// `[0, 2*mean_gap_us]`. `0` = a single burst at t=0.
    pub mean_gap_us: u64,
    /// Relative deadline carried by every request (µs).
    pub deadline_us: u64,
    /// Specs cycled through by counter stream (tenant `i` uses
    /// `specs[k_i]`); empty → requests omit `mult`.
    pub specs: Vec<String>,
}

/// Build a deterministic synthetic trace: inter-arrival gaps, per-
/// request spec choice and input pixels all come from counter-mode
/// Threefry streams keyed on `spec.seed` — no wall clock, no shared
/// RNG state, so the trace is identical on every machine.
pub fn synth_trace(spec: &TraceSpec, input_elems: usize) -> Vec<TimedRequest> {
    let mut out = Vec::with_capacity(spec.requests);
    let mut t = 0u64;
    for i in 0..spec.requests {
        let step = i as u64;
        if spec.mean_gap_us > 0 {
            let gap = u64::from(counter_split(spec.seed, TRACE_GAP_STREAM, step))
                % (2 * spec.mean_gap_us + 1);
            t = t.saturating_add(gap);
        }
        let mult = if spec.specs.is_empty() {
            None
        } else {
            let k = counter_split(spec.seed, TRACE_SPEC_STREAM, step) as usize
                % spec.specs.len();
            spec.specs.get(k).cloned()
        };
        let pixel_seed = counter_split(spec.seed, TRACE_INPUT_STREAM, step);
        let input = counter_normal(pixel_seed, 0, 0, input_elems);
        out.push(TimedRequest {
            arrival_us: t,
            request: InferRequest {
                id: step,
                tenant: format!("tenant-{}", step % 4),
                mult,
                deadline_us: spec.deadline_us,
                input,
            },
        });
    }
    out
}

/// Deterministic replay summary — everything two runs must agree on.
#[derive(Debug, Default)]
pub struct ReplaySummary {
    pub responses: Vec<InferResponse>,
    pub rejects: Vec<InferReject>,
    /// Virtual timestamp of the last processed event.
    pub end_us: u64,
}

/// Replay a timed trace on a virtual clock: arrivals and batcher
/// events interleave in timestamp order (ties: events first, so a due
/// flush never absorbs a later-timestamped arrival). Fully drains the
/// queue after the last arrival.
pub fn replay(server: &mut Server, trace: &[TimedRequest]) -> anyhow::Result<ReplaySummary> {
    let clock = VirtualClock::new(0);
    let mut summary = ReplaySummary::default();
    for timed in trace {
        // Fire every batcher event due strictly before this arrival.
        while let Some(event) = server.next_event_us(clock.now_us()) {
            if event >= timed.arrival_us {
                break;
            }
            clock.advance_to(event);
            let r = server.poll(clock.now_us())?;
            summary.responses.extend(r.responses);
            summary.rejects.extend(r.rejects);
        }
        clock.advance_to(timed.arrival_us);
        if let Err(reject) = server.submit(timed.request.clone(), clock.now_us()) {
            summary.rejects.push(reject);
        }
        // A full lane flushes at admission time, not at the next
        // arrival: poll when an event is already due.
        if let Some(event) = server.next_event_us(clock.now_us()) {
            if event <= clock.now_us() {
                let r = server.poll(clock.now_us())?;
                summary.responses.extend(r.responses);
                summary.rejects.extend(r.rejects);
            }
        }
    }
    let r = server.drain(clock.now_us())?;
    summary.responses.extend(r.responses);
    summary.rejects.extend(r.rejects);
    summary.end_us = clock.now_us().max(server.busy_until_us);
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mult::MultSpec;

    fn server(cfg: &ServeConfig, specs: &[&str]) -> Server {
        let parsed: Vec<MultSpec> =
            specs.iter().map(|s| MultSpec::parse(s).unwrap()).collect();
        let session =
            InferenceSession::from_fresh("micro", 7, &parsed, cfg.max_specs, 11).unwrap();
        Server::new(session, cfg).unwrap()
    }

    fn cfg() -> ServeConfig {
        ServeConfig {
            batch_window_us: 1_000,
            max_batch: 4,
            queue_capacity: 16,
            max_specs: 4,
            service_estimate_us: 500,
            max_request_bytes: 1 << 16,
        }
    }

    fn request(id: u64, input_elems: usize, deadline_us: u64) -> InferRequest {
        InferRequest {
            id,
            tenant: "t".into(),
            mult: None,
            deadline_us,
            input: vec![0.25; input_elems],
        }
    }

    #[test]
    fn submit_validates_before_queueing() {
        let c = cfg();
        let mut s = server(&c, &["exact"]);
        let elems = s.session().input_elems();
        // Wrong input length.
        let r = s.submit(request(1, elems + 1, 1000), 0).unwrap_err();
        assert_eq!(r.reason, RejectReason::BadInput);
        // Unknown spec.
        let mut req = request(2, elems, 1000);
        req.mult = Some("drum6".into());
        let r = s.submit(req, 0).unwrap_err();
        assert_eq!(r.reason, RejectReason::BadInput);
        // Zero deadline.
        let r = s.submit(request(3, elems, 0), 0).unwrap_err();
        assert_eq!(r.reason, RejectReason::BadInput);
        // Unparsable spec.
        let mut req = request(4, elems, 1000);
        req.mult = Some("zorble9".into());
        let r = s.submit(req, 0).unwrap_err();
        assert_eq!(r.reason, RejectReason::BadInput);
        assert_eq!(s.queue_len(), 0);
        assert_eq!(s.stats().rejected_bad_input, 4);
    }

    #[test]
    fn queue_overflow_is_typed() {
        let c = ServeConfig { queue_capacity: 4, ..cfg() };
        let mut s = server(&c, &["exact"]);
        let elems = s.session().input_elems();
        for i in 0..4 {
            // Far deadlines so nothing flushes or expires.
            s.submit(request(i, elems, 10_000_000), 0).unwrap();
        }
        // Capacity 4 = max_batch: the 5th is rejected before queueing.
        let r = s.submit(request(9, elems, 10_000_000), 0).unwrap_err();
        assert_eq!(r.reason, RejectReason::QueueFull);
        assert_eq!(s.stats().rejected_queue, 1);
    }

    #[test]
    fn responses_carry_batch_size_and_latency() {
        let c = cfg();
        let mut s = server(&c, &["exact"]);
        let elems = s.session().input_elems();
        for i in 0..4 {
            s.submit(request(i, elems, 100_000), 10).unwrap();
        }
        // Lane full → flush at poll; completion = 10 + 500.
        let out = s.poll(10).unwrap();
        assert_eq!(out.responses.len(), 4);
        for resp in &out.responses {
            assert_eq!(resp.batch, 4);
            assert_eq!(resp.latency_us, 500);
            assert_eq!(resp.mult, "exact");
            assert!(resp.class < s.session().num_classes());
        }
        assert_eq!(s.stats().completed, 4);
        assert_eq!(s.stats().batches, 1);
        assert_eq!(s.stats().latency.percentile_us(50.0), 500);
    }

    #[test]
    fn replay_low_load_completes_everything() {
        let c = cfg();
        let mut s = server(&c, &["exact", "drum6"]);
        let trace = synth_trace(
            &TraceSpec {
                seed: 33,
                requests: 24,
                mean_gap_us: 2_000,
                deadline_us: 200_000,
                specs: vec!["exact".into(), "drum6".into()],
            },
            s.session().input_elems(),
        );
        let summary = replay(&mut s, &trace).unwrap();
        assert_eq!(summary.responses.len(), 24, "rejects: {:?}", summary.rejects);
        assert!(summary.rejects.is_empty());
        assert_eq!(s.queue_len(), 0);
    }

    #[test]
    fn replay_burst_overload_sheds_with_typed_rejections() {
        let c = ServeConfig { queue_capacity: 8, ..cfg() };
        let mut s = server(&c, &["exact"]);
        let trace = synth_trace(
            &TraceSpec {
                seed: 5,
                requests: 32,
                mean_gap_us: 0, // single burst at t=0
                deadline_us: 1_200,
                specs: vec![],
            },
            s.session().input_elems(),
        );
        let summary = replay(&mut s, &trace).unwrap();
        let st = s.stats();
        assert_eq!(
            st.completed + st.rejected_queue + st.rejected_deadline,
            32,
            "every request is answered exactly once"
        );
        assert!(st.rejected_queue > 0, "burst past capacity must shed");
        assert!(st.completed > 0, "head of the burst must be served");
        assert_eq!(
            summary.responses.len() as u64 + summary.rejects.len() as u64,
            32
        );
    }

    #[test]
    fn identical_traces_replay_bit_identically() {
        let build = || {
            let c = cfg();
            let mut s = server(&c, &["exact", "drum6", "sdrum6"]);
            let trace = synth_trace(
                &TraceSpec {
                    seed: 77,
                    requests: 40,
                    mean_gap_us: 400,
                    deadline_us: 5_000,
                    specs: vec!["exact".into(), "drum6".into(), "sdrum6".into()],
                },
                s.session().input_elems(),
            );
            let summary = replay(&mut s, &trace).unwrap();
            (summary, s)
        };
        let (a, sa) = build();
        let (b, sb) = build();
        assert_eq!(a.responses, b.responses);
        assert_eq!(a.rejects, b.rejects);
        assert_eq!(sa.batch_log(), sb.batch_log());
    }
}
