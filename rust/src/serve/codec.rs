//! Typed wire codec for the serving path.
//!
//! Requests and responses are explicit structs converted to and from
//! [`crate::json::Value`] — not ad-hoc value poking — so every field
//! has one documented type and one decode error message. Decoding runs
//! through [`Value::parse_bytes`], which enforces the byte cap and
//! classifies hostile inputs (oversized / non-UTF-8 / duplicate keys /
//! grammar) before any field logic runs.
//!
//! Numbers ride JSON's f64: request ids are exact up to 2^53, far past
//! any real request volume, and microsecond budgets up to ~285 years.

use anyhow::{bail, Context, Result};

use crate::json::{self, Value};

/// One inference request.
///
/// | field         | type     | meaning                                        |
/// |---------------|----------|------------------------------------------------|
/// | `id`          | integer  | caller-chosen request id, echoed in the reply  |
/// | `tenant`      | string   | tenant name; routes to that tenant's `mult`    |
/// | `mult`        | string?  | multiplier spec override (canonical grammar);  |
/// |               |          | omitted → the server's default spec            |
/// | `deadline_us` | integer  | relative completion budget in µs from admission|
/// | `input`       | [number] | one flat `[hw, hw, ch]` example, f32           |
#[derive(Debug, Clone, PartialEq)]
pub struct InferRequest {
    pub id: u64,
    pub tenant: String,
    pub mult: Option<String>,
    pub deadline_us: u64,
    pub input: Vec<f32>,
}

/// One successful inference reply.
#[derive(Debug, Clone, PartialEq)]
pub struct InferResponse {
    /// Echo of the request id.
    pub id: u64,
    /// Echo of the tenant.
    pub tenant: String,
    /// Canonical multiplier spec the request was served under.
    pub mult: String,
    /// Argmax class of the logits.
    pub class: usize,
    /// Raw logits, one per class.
    pub logits: Vec<f32>,
    /// Size of the GEMM batch this request rode in.
    pub batch: usize,
    /// Admission-to-completion latency in µs.
    pub latency_us: u64,
}

/// Why a request was rejected instead of served. Rejection is a typed
/// reply, never a panic and never a silent drop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// The bounded request queue was at capacity at admission.
    QueueFull,
    /// The deadline could not (or can no longer) be met; the request
    /// was shed *before* spending GEMM time on it.
    DeadlineMissed,
    /// The request failed validation: unknown spec, wrong input
    /// length, zero deadline, or an undecodable body.
    BadInput,
}

impl RejectReason {
    pub fn name(self) -> &'static str {
        match self {
            RejectReason::QueueFull => "queue-full",
            RejectReason::DeadlineMissed => "deadline-missed",
            RejectReason::BadInput => "bad-input",
        }
    }
}

/// One rejection reply.
#[derive(Debug, Clone, PartialEq)]
pub struct InferReject {
    pub id: u64,
    pub tenant: String,
    pub reason: RejectReason,
    /// Human-readable detail (one line).
    pub detail: String,
}

impl InferRequest {
    pub fn to_value(&self) -> Value {
        let mut fields = vec![
            ("id", Value::from(self.id as f64)),
            ("tenant", Value::from(self.tenant.clone())),
            ("deadline_us", Value::from(self.deadline_us as f64)),
            (
                "input",
                Value::Array(self.input.iter().map(|&v| Value::from(v as f64)).collect()),
            ),
        ];
        if let Some(m) = &self.mult {
            fields.push(("mult", Value::from(m.clone())));
        }
        json::object(fields)
    }

    pub fn from_value(v: &Value) -> Result<Self> {
        let id = field_u64(v, "id")?;
        let tenant = v
            .get("tenant")
            .and_then(|t| t.as_str())
            .context("request field `tenant`")?
            .to_string();
        let mult = match v.get("mult") {
            Ok(m) => Some(m.as_str().context("request field `mult`")?.to_string()),
            Err(_) => None,
        };
        let deadline_us = field_u64(v, "deadline_us")?;
        let input = v
            .get("input")
            .and_then(|a| a.as_array())
            .context("request field `input`")?
            .iter()
            .map(|x| x.as_f64().map(|f| f as f32))
            .collect::<Result<Vec<f32>>>()
            .context("request field `input`")?;
        Ok(InferRequest { id, tenant, mult, deadline_us, input })
    }

    /// Decode one request from raw bytes under the configured byte
    /// cap. Errors are typed: the transport layer maps
    /// [`crate::json::classify`]-able faults and field errors alike to
    /// [`RejectReason::BadInput`].
    pub fn decode(bytes: &[u8], max_bytes: usize) -> Result<Self> {
        let v = Value::parse_bytes(bytes, max_bytes).context("decoding request body")?;
        Self::from_value(&v)
    }
}

impl InferResponse {
    pub fn to_value(&self) -> Value {
        json::object(vec![
            ("id", Value::from(self.id as f64)),
            ("tenant", Value::from(self.tenant.clone())),
            ("mult", Value::from(self.mult.clone())),
            ("class", Value::from(self.class)),
            (
                "logits",
                Value::Array(self.logits.iter().map(|&v| Value::from(v as f64)).collect()),
            ),
            ("batch", Value::from(self.batch)),
            ("latency_us", Value::from(self.latency_us as f64)),
        ])
    }

    pub fn from_value(v: &Value) -> Result<Self> {
        Ok(InferResponse {
            id: field_u64(v, "id")?,
            tenant: v
                .get("tenant")
                .and_then(|t| t.as_str())
                .context("response field `tenant`")?
                .to_string(),
            mult: v
                .get("mult")
                .and_then(|t| t.as_str())
                .context("response field `mult`")?
                .to_string(),
            class: v
                .get("class")
                .and_then(|c| c.as_usize())
                .context("response field `class`")?,
            logits: v
                .get("logits")
                .and_then(|a| a.as_array())
                .context("response field `logits`")?
                .iter()
                .map(|x| x.as_f64().map(|f| f as f32))
                .collect::<Result<Vec<f32>>>()
                .context("response field `logits`")?,
            batch: v
                .get("batch")
                .and_then(|b| b.as_usize())
                .context("response field `batch`")?,
            latency_us: field_u64(v, "latency_us")?,
        })
    }
}

impl InferReject {
    pub fn to_value(&self) -> Value {
        json::object(vec![
            ("id", Value::from(self.id as f64)),
            ("tenant", Value::from(self.tenant.clone())),
            ("reject", Value::from(self.reason.name())),
            ("detail", Value::from(self.detail.clone())),
        ])
    }

    pub fn from_value(v: &Value) -> Result<Self> {
        let name = v
            .get("reject")
            .and_then(|r| r.as_str())
            .context("reject field `reject`")?;
        let reason = match name {
            "queue-full" => RejectReason::QueueFull,
            "deadline-missed" => RejectReason::DeadlineMissed,
            "bad-input" => RejectReason::BadInput,
            other => bail!("unknown reject reason {other:?}"),
        };
        Ok(InferReject {
            id: field_u64(v, "id")?,
            tenant: v
                .get("tenant")
                .and_then(|t| t.as_str())
                .context("reject field `tenant`")?
                .to_string(),
            reason,
            detail: v
                .get("detail")
                .and_then(|d| d.as_str())
                .context("reject field `detail`")?
                .to_string(),
        })
    }
}

/// Non-negative integer field decoded to u64 (exact up to 2^53).
fn field_u64(v: &Value, key: &str) -> Result<u64> {
    let n = v
        .get(key)
        .and_then(|x| x.as_f64())
        .with_context(|| format!("request field `{key}`"))?;
    if !(n.is_finite() && n >= 0.0 && n.fract() == 0.0 && n <= 9_007_199_254_740_992.0) {
        bail!("field `{key}` must be a non-negative integer, got {n}");
    }
    Ok(n as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> InferRequest {
        InferRequest {
            id: 42,
            tenant: "acme".into(),
            mult: Some("drum6".into()),
            deadline_us: 5000,
            input: vec![0.5, -1.0, 2.0],
        }
    }

    #[test]
    fn request_roundtrips() {
        let r = req();
        let v = r.to_value();
        let back = InferRequest::from_value(&v).unwrap();
        assert_eq!(back, r);
        // And through the byte path.
        let bytes = v.to_string().into_bytes();
        let back = InferRequest::decode(&bytes, 1 << 20).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn request_without_mult_roundtrips() {
        let mut r = req();
        r.mult = None;
        let back = InferRequest::from_value(&r.to_value()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn response_roundtrips() {
        let r = InferResponse {
            id: 7,
            tenant: "acme".into(),
            mult: "exact".into(),
            class: 3,
            logits: vec![0.1, 0.2, 0.3, 0.9],
            batch: 8,
            latency_us: 1234,
        };
        assert_eq!(InferResponse::from_value(&r.to_value()).unwrap(), r);
    }

    #[test]
    fn reject_roundtrips_all_reasons() {
        for reason in [
            RejectReason::QueueFull,
            RejectReason::DeadlineMissed,
            RejectReason::BadInput,
        ] {
            let r = InferReject {
                id: 1,
                tenant: "t".into(),
                reason,
                detail: "d".into(),
            };
            assert_eq!(InferReject::from_value(&r.to_value()).unwrap(), r);
        }
    }

    #[test]
    fn decode_rejects_hostile_bodies_with_typed_errors() {
        use crate::json::JsonFaultClass;
        // Oversized.
        let body = req().to_value().to_string().into_bytes();
        let err = InferRequest::decode(&body, 8).unwrap_err();
        assert_eq!(json::classify(&err), Some(JsonFaultClass::Oversized));
        // Non-UTF-8.
        let err = InferRequest::decode(&[0xFF, 0xFE], 1024).unwrap_err();
        assert_eq!(json::classify(&err), Some(JsonFaultClass::NonUtf8));
        // Duplicate keys.
        let err = InferRequest::decode(br#"{"id":1,"id":2}"#, 1024).unwrap_err();
        assert_eq!(json::classify(&err), Some(JsonFaultClass::DuplicateKey));
        // Grammar garbage.
        let err = InferRequest::decode(b"not json", 1024).unwrap_err();
        assert_eq!(json::classify(&err), Some(JsonFaultClass::Syntax));
    }

    #[test]
    fn missing_and_malformed_fields_are_errors() {
        let v = Value::parse(r#"{"id": 1}"#).unwrap();
        assert!(InferRequest::from_value(&v).is_err());
        let v = Value::parse(
            r#"{"id": -3, "tenant": "t", "deadline_us": 1, "input": []}"#,
        )
        .unwrap();
        assert!(InferRequest::from_value(&v).is_err(), "negative id");
        let v = Value::parse(
            r#"{"id": 1.5, "tenant": "t", "deadline_us": 1, "input": []}"#,
        )
        .unwrap();
        assert!(InferRequest::from_value(&v).is_err(), "fractional id");
    }
}
