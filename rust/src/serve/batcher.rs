//! Deadline-aware dynamic batching policy.
//!
//! The batcher turns queued requests into GEMM-shaped batches under
//! three triggers, checked in priority order per lane:
//!
//! 1. **DeadlineImminent** — the lane's earliest absolute deadline is
//!    within two service quanta of the effective start time: waiting
//!    any longer risks converting a servable request into a miss.
//! 2. **BatchFull** — the lane holds at least `max_batch` requests: a
//!    full GEMM batch is ready, flush it.
//! 3. **WindowElapsed** — the lane's oldest request has waited
//!    `batch_window_us`: bounded coalescing latency for quiet lanes.
//!
//! All decision math is pure `u64` microsecond arithmetic over the
//! caller-supplied `now` (detlint D2: no wall-clock reads here), lanes
//! are visited in the queue's canonical order, and requests flush in
//! FIFO order — so the batch sequence is a deterministic function of
//! `(arrival trace, policy)` at any thread count.
//!
//! One server executes batches serially: `busy_until_us` models the
//! earliest time a new flush can *start*. Requests whose deadline
//! precedes `start + service_estimate_us` are shed as typed
//! `deadline-missed` rejections before any GEMM time is spent on them
//! — under overload the queue sheds load instead of serving answers
//! that are already too late.

use super::queue::{Pending, ServeQueue};

/// Tunable batching policy (see [`crate::config::ServeConfig`]).
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Max requests per GEMM batch.
    pub max_batch: usize,
    /// Max coalescing wait for a lane's oldest request (µs).
    pub batch_window_us: u64,
    /// Deterministic per-batch service-time model (µs): used for
    /// deadline feasibility, imminence, and modeled completion times.
    pub service_estimate_us: u64,
}

/// Why a batch was flushed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlushTrigger {
    DeadlineImminent,
    BatchFull,
    WindowElapsed,
}

impl FlushTrigger {
    pub fn name(self) -> &'static str {
        match self {
            FlushTrigger::DeadlineImminent => "deadline-imminent",
            FlushTrigger::BatchFull => "batch-full",
            FlushTrigger::WindowElapsed => "window-elapsed",
        }
    }
}

/// One flushed batch: requests for exactly one spec, never mixed.
#[derive(Debug, Clone)]
pub struct Batch {
    /// Canonical spec every request in this batch runs under.
    pub spec: String,
    pub requests: Vec<Pending>,
    pub trigger: FlushTrigger,
    /// Decision time of the flush (µs).
    pub flush_us: u64,
    /// Modeled service start (µs): `max(flush_us, busy_until)` at
    /// decision time.
    pub start_us: u64,
    /// Modeled completion (µs): `start_us + service_estimate_us`.
    /// Response latency is `complete_us - arrival_us`.
    pub complete_us: u64,
}

/// Result of one poll: batches to execute and requests shed because
/// their deadline can no longer be met.
#[derive(Debug, Default)]
pub struct PollOutcome {
    pub batches: Vec<Batch>,
    pub expired: Vec<Pending>,
    /// Server busy horizon after the flushed batches (µs).
    pub busy_until_us: u64,
}

#[derive(Debug, Clone, Copy)]
pub struct Batcher {
    policy: BatchPolicy,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { policy }
    }

    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    /// Evaluate triggers at `now_us` with the server busy until
    /// `busy_until_us`, flushing every lane whose condition holds.
    /// Mutates the queue (flushed and expired requests leave it).
    pub fn poll(
        &self,
        queue: &mut ServeQueue,
        now_us: u64,
        busy_until_us: u64,
    ) -> PollOutcome {
        let svc = self.policy.service_estimate_us;
        let mut out = PollOutcome {
            batches: Vec::new(),
            expired: Vec::new(),
            busy_until_us,
        };
        for spec in queue.specs() {
            loop {
                let start = now_us.max(out.busy_until_us);
                // Shed requests that cannot complete even if flushed
                // right now: completion would be start + svc.
                out.expired
                    .extend(queue.drain_expired(&spec, start.saturating_add(svc)));
                let Some(lane) = queue.lane_summary(&spec) else {
                    break;
                };
                let imminent =
                    lane.deadline_min_us <= start.saturating_add(2 * svc);
                let trigger = if imminent {
                    FlushTrigger::DeadlineImminent
                } else if lane.len >= self.policy.max_batch {
                    FlushTrigger::BatchFull
                } else if now_us
                    >= lane.oldest_arrival_us.saturating_add(self.policy.batch_window_us)
                {
                    FlushTrigger::WindowElapsed
                } else {
                    break;
                };
                let requests = queue.take_front(&spec, self.policy.max_batch);
                if requests.is_empty() {
                    break;
                }
                let complete = start.saturating_add(svc);
                out.busy_until_us = complete;
                out.batches.push(Batch {
                    spec: spec.clone(),
                    requests,
                    trigger,
                    flush_us: now_us,
                    start_us: start,
                    complete_us: complete,
                });
            }
        }
        out
    }

    /// Earliest future time a trigger could fire, given the queue's
    /// current contents — the virtual driver's next wake-up. `None`
    /// when the queue is empty. A full lane reports `now` is already
    /// due (returns a time ≤ now).
    pub fn next_event_us(&self, queue: &ServeQueue, now_us: u64) -> Option<u64> {
        let svc = self.policy.service_estimate_us;
        let mut next: Option<u64> = None;
        for spec in queue.specs() {
            let Some(lane) = queue.lane_summary(&spec) else {
                continue;
            };
            let mut lane_next = if lane.len >= self.policy.max_batch {
                now_us
            } else {
                lane.oldest_arrival_us.saturating_add(self.policy.batch_window_us)
            };
            let imminence = lane.deadline_min_us.saturating_sub(2 * svc);
            lane_next = lane_next.min(imminence);
            next = Some(next.map_or(lane_next, |n| n.min(lane_next)));
        }
        next
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(id: u64, arrival: u64, deadline: u64) -> Pending {
        Pending {
            id,
            tenant: "t".into(),
            arrival_us: arrival,
            deadline_us: deadline,
            input: vec![0.0],
            seq: 0,
        }
    }

    fn batcher() -> Batcher {
        Batcher::new(BatchPolicy {
            max_batch: 4,
            batch_window_us: 1000,
            service_estimate_us: 100,
        })
    }

    #[test]
    fn quiet_lane_waits_for_window() {
        let b = batcher();
        let mut q = ServeQueue::new(16);
        q.push("exact", p(1, 0, 1_000_000)).unwrap();
        // Before the window: nothing flushes.
        let out = b.poll(&mut q, 500, 0);
        assert!(out.batches.is_empty());
        assert_eq!(q.len(), 1);
        // At the window boundary: WindowElapsed.
        let out = b.poll(&mut q, 1000, 0);
        assert_eq!(out.batches.len(), 1);
        assert_eq!(out.batches[0].trigger, FlushTrigger::WindowElapsed);
    }

    #[test]
    fn full_lane_flushes_immediately() {
        let b = batcher();
        let mut q = ServeQueue::new(16);
        for i in 0..4 {
            q.push("exact", p(i, 0, 1_000_000)).unwrap();
        }
        let out = b.poll(&mut q, 0, 0);
        assert_eq!(out.batches.len(), 1);
        assert_eq!(out.batches[0].trigger, FlushTrigger::BatchFull);
        assert_eq!(out.batches[0].requests.len(), 4);
    }

    #[test]
    fn deadline_imminent_beats_batch_full() {
        let b = batcher();
        let mut q = ServeQueue::new(16);
        // Full lane AND an imminent deadline: the label must be
        // DeadlineImminent (priority over BatchFull).
        for i in 0..4 {
            q.push("exact", p(i, 0, 150)).unwrap();
        }
        let out = b.poll(&mut q, 0, 0);
        assert_eq!(out.batches.len(), 1);
        assert_eq!(out.batches[0].trigger, FlushTrigger::DeadlineImminent);
    }

    #[test]
    fn deadline_imminent_flushes_a_short_batch_early() {
        let b = batcher();
        let mut q = ServeQueue::new(16);
        // One request, window not elapsed, lane not full — but the
        // deadline is within 2·svc of now: flush anyway.
        q.push("exact", p(1, 0, 180)).unwrap();
        let out = b.poll(&mut q, 0, 0);
        assert_eq!(out.batches.len(), 1);
        assert_eq!(out.batches[0].trigger, FlushTrigger::DeadlineImminent);
        assert_eq!(out.batches[0].requests.len(), 1);
    }

    #[test]
    fn unmeetable_deadlines_are_shed_not_served() {
        let b = batcher();
        let mut q = ServeQueue::new(16);
        // Completion would be at 100; deadline 50 is hopeless.
        q.push("exact", p(1, 0, 50)).unwrap();
        let out = b.poll(&mut q, 0, 0);
        assert!(out.batches.is_empty());
        assert_eq!(out.expired.len(), 1);
        assert_eq!(out.expired[0].id, 1);
        assert!(q.is_empty());
    }

    #[test]
    fn busy_horizon_serializes_batches_and_sheds_late_requests() {
        let b = batcher();
        let mut q = ServeQueue::new(64);
        // 12 requests at t=0 with deadlines that allow ~2 batches:
        // batch 1 completes at 100, batch 2 at 200, batch 3 at 300.
        for i in 0..12 {
            q.push("exact", p(i, 0, 250)).unwrap();
        }
        let out = b.poll(&mut q, 0, 0);
        // Batch 1: start 0 → complete 100. Batch 2: start 100 →
        // complete 200. Batch 3 would complete at 300 > 250: shed.
        assert_eq!(out.batches.len(), 2);
        assert_eq!(out.batches[0].complete_us, 100);
        assert_eq!(out.batches[1].complete_us, 200);
        assert_eq!(out.expired.len(), 4);
        assert_eq!(out.busy_until_us, 200);
        assert!(q.is_empty());
    }

    #[test]
    fn specs_never_mix_within_a_batch() {
        let b = batcher();
        let mut q = ServeQueue::new(16);
        q.push("drum6", p(1, 0, 1_000_000)).unwrap();
        q.push("exact", p(2, 0, 1_000_000)).unwrap();
        q.push("drum6", p(3, 0, 1_000_000)).unwrap();
        let out = b.poll(&mut q, 5000, 0);
        assert_eq!(out.batches.len(), 2);
        for batch in &out.batches {
            let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
            match batch.spec.as_str() {
                "drum6" => assert_eq!(ids, [1, 3]),
                "exact" => assert_eq!(ids, [2]),
                other => panic!("unexpected spec {other}"),
            }
        }
    }

    #[test]
    fn next_event_is_min_of_window_and_imminence() {
        let b = batcher();
        let mut q = ServeQueue::new(16);
        // Window fires at 0+1000; imminence at 5000-200=4800.
        q.push("exact", p(1, 0, 5000)).unwrap();
        assert_eq!(b.next_event_us(&q, 0), Some(1000));
        // Tight deadline: imminence (300-200=100) precedes the window.
        q.push("drum6", p(2, 0, 300)).unwrap();
        assert_eq!(b.next_event_us(&q, 0), Some(100));
        assert_eq!(b.next_event_us(&ServeQueue::new(4), 0), None);
    }

    #[test]
    fn oversize_lane_drains_in_fifo_chunks() {
        let b = batcher();
        let mut q = ServeQueue::new(64);
        for i in 0..10 {
            q.push("exact", p(i, 0, 1_000_000)).unwrap();
        }
        let out = b.poll(&mut q, 0, 0);
        // 4 + 4 (BatchFull) + 2 (WindowElapsed? no — window not
        // elapsed at t=0, deadline far) → the tail stays queued.
        assert_eq!(out.batches.len(), 2);
        let first: Vec<u64> = out.batches[0].requests.iter().map(|r| r.id).collect();
        assert_eq!(first, [0, 1, 2, 3]);
        assert_eq!(q.len(), 2);
    }
}
