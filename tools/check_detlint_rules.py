#!/usr/bin/env python3
"""detlint mirror — stdlib-only port of rust/analyzers/detlint.

Authoring containers for this repo have no Rust toolchain, so this
mirror is the executable validation for the detlint v2 engine: it
re-implements the token lexer, delimiter matching, binding table, scan
profiles, all nine rules, and the report ordering, then (with no
arguments) it

  1. checks the fixture corpus against its pinned expectations,
  2. runs embedded scenario checks (the lib.rs unit tests, ported), and
  3. scans the CI tree set expecting a clean report.

`--scan <paths...> [--json] [--strict-stale]` mirrors the CLI (minus
`--baseline`); with `--json` the output is byte-identical to
`detlint --json` over the same paths, so CI can diff the two engines.

Exit codes: 0 = all checks pass (or scan clean), 1 = findings/failures,
2 = usage error. Mirrors `tools/check_simd_recipes.py` in spirit: no
third-party imports, runnable anywhere.
"""

import os
import sys
from bisect import bisect_right

# --------------------------------------------------------------------------
# Rule tables (keep in lockstep with rust/analyzers/detlint/src/lib.rs).
# --------------------------------------------------------------------------

RULE_IDS = ["D1", "D1v2", "D2", "D3", "P1", "P2", "S1", "U1", "C1"]

D1_SCOPE = [
    "mult", "runtime", "coordinator", "rng", "tensor", "data", "config",
    "metrics", "benchkit", "report", "json", "checkpoint", "serve",
]
D2_SCOPE = ["mult", "runtime/native", "rng", "tensor", "data", "coordinator", "serve"]
D3_SPAWN_EXEMPT = ["parallel"]
D3_REDUCE_SCOPE = ["mult", "runtime/native", "tensor", "data", "rng", "serve"]
P1_SCOPE = [
    "checkpoint", "coordinator/health.rs", "coordinator/recovery.rs",
    "coordinator/trainer.rs", "testkit/faults.rs", "serve",
]
P2_SCOPE = P1_SCOPE
S1_SCOPE = ["mult"]
ALL_SCOPE = ["*"]

INT_TYPES = {
    "i8", "i16", "i32", "i64", "i128", "isize",
    "u8", "u16", "u32", "u64", "u128", "usize",
}

NON_INDEX_KEYWORDS = {
    "as", "async", "await", "box", "break", "const", "continue", "crate",
    "dyn", "else", "enum", "extern", "fn", "for", "if", "impl", "in", "let",
    "loop", "match", "mod", "move", "mut", "pub", "ref", "return", "use",
    "where",
}

ITER_METHODS = {
    "drain", "into_iter", "into_keys", "into_values", "iter", "iter_mut",
    "keys", "values", "values_mut",
}

KERNEL_FAMILIES = {
    ("UnsignedKernel", "Exact"): "exact",
    ("UnsignedKernel", "Drum"): "drum",
    ("UnsignedKernel", "Trunc"): "trunc",
    ("UnsignedKernel", "Mitchell"): "mitchell",
    ("UnsignedKernel", "Flat"): "lut",
    ("SignedKernel", "Exact"): "sexact",
    ("SignedKernel", "SDrum"): "sdrum",
    ("SignedKernel", "Booth"): "booth",
    ("SignedKernel", "Flat"): "slut",
}

IDENT, NUM, STR, CHAR, LIFETIME, PUNCT = range(6)


def is_ident_char(c):
    return c == "_" or (c.isascii() and c.isalnum())


# --------------------------------------------------------------------------
# Lexer.
# --------------------------------------------------------------------------

def lex(src):
    n = len(src)
    line_starts = [0]
    for i, c in enumerate(src):
        if c == "\n":
            line_starts.append(i + 1)

    def line_of(pos):
        return bisect_right(line_starts, pos)

    toks = []   # (kind, pos, end, line, text)
    comments = []  # (line, text)
    i = 0
    while i < n:
        c = src[i]
        if c in " \t\r\n":
            i += 1
            continue
        if src.startswith("//", i):
            j = src.find("\n", i)
            j = n if j < 0 else j
            comments.append((line_of(i), src[i:j]))
            i = j
            continue
        if src.startswith("/*", i):
            depth = 1
            j = i + 2
            while j < n and depth > 0:
                if src.startswith("/*", j):
                    depth += 1
                    j += 2
                elif src.startswith("*/", j):
                    depth -= 1
                    j += 2
                else:
                    j += 1
            i = j
            continue
        left_bound = i == 0 or not is_ident_char(src[i - 1])
        # Raw (and byte-raw) strings.
        if left_bound and (c == "r" or (c == "b" and src.startswith("br", i))):
            k = i + 2 if c == "b" else i + 1
            hashes = 0
            while k < n and src[k] == "#":
                hashes += 1
                k += 1
            if k < n and src[k] == '"':
                j = k + 1
                end = n
                while True:
                    q = src.find('"', j)
                    if q < 0:
                        end = n
                        break
                    h = 0
                    while h < hashes and q + 1 + h < n and src[q + 1 + h] == "#":
                        h += 1
                    if h == hashes:
                        end = q + 1 + hashes
                        break
                    j = q + 1
                toks.append((STR, i, end, line_of(i), src[i:end]))
                i = end
                continue
        # Plain and byte strings.
        if c == '"' or (left_bound and c == "b" and i + 1 < n and src[i + 1] == '"'):
            q0 = i + 1 if c == "b" else i
            j = q0 + 1
            while j < n:
                if src[j] == "\\":
                    j += 2
                elif src[j] == '"':
                    j += 1
                    break
                else:
                    j += 1
            j = min(j, n)
            toks.append((STR, i, j, line_of(i), src[i:j]))
            i = j
            continue
        # Char literal vs lifetime.
        if c == "'":
            if i + 1 < n and src[i + 1] == "\\":
                q = src.find("'", i + 2)
                j = n if q < 0 else q + 1
                toks.append((CHAR, i, j, line_of(i), src[i:j]))
                i = j
                continue
            if i + 2 < n and src[i + 2] == "'":
                toks.append((CHAR, i, i + 3, line_of(i), src[i:i + 3]))
                i += 3
                continue
            j = i + 1
            while j < n and is_ident_char(src[j]):
                j += 1
            toks.append((LIFETIME, i, j, line_of(i), src[i:j]))
            i = j
            continue
        # Number (ident-ish suffix chars, optional `.digits` fraction).
        if c.isdigit():
            j = i + 1
            while j < n and is_ident_char(src[j]):
                j += 1
            if j + 1 < n and src[j] == "." and src[j + 1].isdigit():
                j += 1
                while j < n and is_ident_char(src[j]):
                    j += 1
            toks.append((NUM, i, j, line_of(i), src[i:j]))
            i = j
            continue
        if is_ident_char(c):
            j = i + 1
            while j < n and is_ident_char(src[j]):
                j += 1
            toks.append((IDENT, i, j, line_of(i), src[i:j]))
            i = j
            continue
        toks.append((PUNCT, i, i + 1, line_of(i), c))
        i += 1
    return toks, comments, line_starts


class Fx:
    def __init__(self, src):
        toks, comments, line_starts = lex(src)
        self.src = src
        self.toks = toks
        self.comments = comments
        self.n_lines = len(line_starts)
        line_has_code = [False] * (self.n_lines + 2)
        for (kind, pos, end, line, _text) in toks:
            b = bisect_right(line_starts, max(end - 1, pos))
            for l in range(line, min(b, self.n_lines) + 1):
                line_has_code[l] = True
        self.line_has_code = line_has_code
        self.partner = self._match_delims()
        self.mask = self._test_mask()

    def text(self, i):
        return self.toks[i][4]

    def kind(self, i):
        return self.toks[i][0]

    def line(self, i):
        return self.toks[i][3]

    def pos(self, i):
        return self.toks[i][1]

    def end(self, i):
        return self.toks[i][2]

    def ident_is(self, i, s):
        return 0 <= i < len(self.toks) and self.toks[i][0] == IDENT and self.toks[i][4] == s

    def punct_is(self, i, c):
        return 0 <= i < len(self.toks) and self.toks[i][0] == PUNCT and self.toks[i][4] == c

    def _match_delims(self):
        partner = [None] * len(self.toks)
        stack = []
        opens = {")": "(", "]": "[", "}": "{"}
        for i, (kind, _pos, _end, _line, text) in enumerate(self.toks):
            if kind != PUNCT:
                continue
            if text in "([{":
                stack.append((text, i))
            elif text in ")]}":
                want = opens[text]
                while stack:
                    oc, oi = stack.pop()
                    if oc == want:
                        partner[oi] = i
                        partner[i] = oi
                        break
        return partner

    def _test_mask(self):
        n = len(self.toks)
        mask = [False] * n
        i = 0
        while i < n:
            attr_end = None
            if self.punct_is(i, "#") and self.punct_is(i + 1, "["):
                if self.ident_is(i + 2, "test") and self.punct_is(i + 3, "]"):
                    attr_end = i + 3
                elif (
                    self.ident_is(i + 2, "cfg")
                    and self.punct_is(i + 3, "(")
                    and self.ident_is(i + 4, "test")
                    and self.punct_is(i + 5, ")")
                    and self.punct_is(i + 6, "]")
                ):
                    attr_end = i + 6
            if attr_end is not None:
                j = attr_end + 1
                end = n
                while j < n:
                    if self.punct_is(j, ";"):
                        end = j + 1
                        break
                    if self.punct_is(j, "{"):
                        p = self.partner[j]
                        end = (p + 1) if p is not None else n
                        break
                    j += 1
                for m in range(i, min(end, n)):
                    mask[m] = True
                i = attr_end + 1
                continue
            i += 1
        return mask

    def stmt_start(self, i):
        j = i
        while j > 0:
            p = j - 1
            if self.punct_is(p, ";") or self.punct_is(p, "{") or self.punct_is(p, "}"):
                break
            j -= 1
        return j

    def float_evidence(self, a, b):
        for i in range(a, min(b, len(self.toks))):
            kind = self.kind(i)
            if kind == IDENT:
                t = self.text(i)
                if t in ("f32", "f64") and not (
                    self.punct_is(i + 1, ":")
                    and self.punct_is(i + 2, ":")
                    and self.ident_is(i + 3, "from_bits")
                ):
                    return True
            elif kind == NUM:
                t = self.text(i)
                for k in range(len(t) - 2):
                    if t[k].isdigit() and t[k + 1] == "." and t[k + 2].isdigit():
                        return True
        return False


# --------------------------------------------------------------------------
# Markers, scopes, profiles.
# --------------------------------------------------------------------------

def parse_marker(text):
    """None = not a marker; ("err", msg) = malformed; ("ok", rules, reason)."""
    t = text.lstrip("/!").lstrip()
    if not t.startswith("detlint:"):
        return None
    rest = t[len("detlint:"):].lstrip()
    if not rest.startswith("allow("):
        return ("err", "expected `allow(<rules>)` after `detlint:`")
    rest = rest[len("allow("):]
    close = rest.find(")")
    if close < 0:
        return ("err", "unclosed `allow(`")
    rules = [s.strip() for s in rest[:close].split(",")]
    rules = [r for r in rules if r]
    if not rules:
        return ("err", "empty rule list in `allow()`")
    for r in rules:
        if r not in RULE_IDS:
            return ("err", "unknown rule `%s` in allow marker" % r)
    tail = rest[close + 1:].lstrip()
    if not tail.startswith("--"):
        return ("err", "marker missing `-- <reason>`")
    reason = tail[2:].strip()
    if not reason:
        return ("err", "marker missing `-- <reason>`")
    return ("ok", rules, reason)


def in_scope(path, scopes):
    if "*" in scopes:
        return True
    segs = [s for s in path.replace("\\", "/").split("/") if s]
    for scope in scopes:
        want = scope.split("/")
        if not want or len(segs) < len(want):
            continue
        for k in range(len(segs) - len(want) + 1):
            if segs[k:k + len(want)] == want:
                return True
    return False


DEFAULT, TESTS, ANALYZER = "default", "tests", "analyzer"


def profile_for(path):
    segs = [s for s in path.replace("\\", "/").split("/") if s]
    if "fixtures" in segs:
        return DEFAULT
    if "analyzers" in segs:
        return ANALYZER
    if "tests" in segs:
        return TESTS
    return DEFAULT


def rule_scope(profile, rule):
    if profile == DEFAULT:
        return {
            "D1": D1_SCOPE, "D1v2": D1_SCOPE, "D2": D2_SCOPE,
            "D3": D3_REDUCE_SCOPE, "P1": P1_SCOPE, "P2": P2_SCOPE,
            "S1": S1_SCOPE, "U1": ALL_SCOPE, "C1": S1_SCOPE,
        }.get(rule)
    if rule in ("D1", "D1v2", "D3", "U1"):
        return ALL_SCOPE
    return None


# --------------------------------------------------------------------------
# Binding table.
# --------------------------------------------------------------------------

def contains_word(hay, word):
    start = 0
    while True:
        p = hay.find(word, start)
        if p < 0:
            return False
        before_ok = p == 0 or not is_ident_char(hay[p - 1])
        after = p + len(word)
        after_ok = after >= len(hay) or not is_ident_char(hay[after])
        if before_ok and after_ok:
            return True
        start = p + 1


def lone_colon(fx, i):
    return (
        fx.punct_is(i, ":")
        and not fx.punct_is(i + 1, ":")
        and not (i > 0 and fx.punct_is(i - 1, ":"))
    )


def collect_bindings(fx):
    n = len(fx.toks)
    out = []  # (name, ty, pos)

    def push_segment(a, b):
        colon = None
        depth = 0
        angle = 0
        for i in range(a, b):
            if fx.kind(i) == PUNCT:
                t = fx.text(i)
                if t in "([{":
                    depth += 1
                elif t in ")]}":
                    depth -= 1
                elif t == "<":
                    angle += 1
                elif t == ">":
                    angle -= 1
            if depth == 0 and angle == 0 and lone_colon(fx, i):
                colon = i
                break
        if colon is None:
            return
        name = None
        pos = None
        for i in range(colon - 1, a - 1, -1):
            if fx.kind(i) == IDENT:
                t = fx.text(i)
                if t not in ("mut", "ref"):
                    name = t
                    pos = fx.pos(i)
                break
        if name is None:
            return
        ty = "".join(fx.text(i) for i in range(colon + 1, b))
        out.append((name, ty, pos))

    def split_segments(opened, close):
        seg = opened + 1
        depth = 0
        angle = 0
        i = opened + 1
        while i <= close:
            boundary = i == close or (depth == 0 and angle <= 0 and fx.punct_is(i, ","))
            if boundary:
                if seg < i:
                    push_segment(seg, i)
                seg = i + 1
                if fx.punct_is(i, ","):
                    angle = max(angle, 0)
            elif fx.kind(i) == PUNCT:
                t = fx.text(i)
                if t in "([{":
                    depth += 1
                elif t in ")]}":
                    depth -= 1
                elif t == "<":
                    angle += 1
                elif t == ">":
                    angle -= 1
            i += 1

    i = 0
    while i < n:
        if fx.ident_is(i, "let"):
            j = i + 1
            if fx.ident_is(j, "mut"):
                j += 1
            if j < n and fx.kind(j) == IDENT:
                name = fx.text(j)
                pos = fx.pos(j)
                k = j + 1
                if lone_colon(fx, k):
                    ty = []
                    m = k + 1
                    angle = 0
                    while m < n:
                        if angle <= 0 and (fx.punct_is(m, "=") or fx.punct_is(m, ";")):
                            break
                        if fx.punct_is(m, "<"):
                            angle += 1
                        elif fx.punct_is(m, ">"):
                            angle -= 1
                        ty.append(fx.text(m))
                        m += 1
                    out.append((name, "".join(ty), pos))
                elif fx.punct_is(k, "=") and not fx.punct_is(k + 1, "="):
                    m = k + 1
                    depth = 0
                    ty = ""
                    while m < n:
                        if depth == 0 and fx.punct_is(m, ";"):
                            break
                        if fx.kind(m) == PUNCT:
                            t = fx.text(m)
                            if t in "([{":
                                depth += 1
                            elif t in ")]}":
                                depth -= 1
                        elif fx.kind(m) == IDENT and fx.text(m) in ("HashMap", "HashSet"):
                            ty = fx.text(m)
                        m += 1
                    if ty:
                        out.append((name, ty, pos))
            i += 1
            continue
        if fx.ident_is(i, "fn"):
            j = i + 1
            angle = 0
            while j < n:
                if fx.punct_is(j, "<"):
                    angle += 1
                elif fx.punct_is(j, ">"):
                    angle -= 1
                elif angle <= 0 and (fx.punct_is(j, "{") or fx.punct_is(j, ";")):
                    break
                elif angle <= 0 and fx.punct_is(j, "("):
                    close = fx.partner[j]
                    if close is not None:
                        split_segments(j, close)
                    break
                j += 1
            i += 1
            continue
        if fx.ident_is(i, "struct") and i + 1 < n and fx.kind(i + 1) == IDENT:
            j = i + 2
            angle = 0
            while j < n:
                if fx.punct_is(j, "<"):
                    angle += 1
                elif fx.punct_is(j, ">"):
                    angle -= 1
                elif angle <= 0 and (fx.punct_is(j, ";") or fx.punct_is(j, "(")):
                    break
                elif angle <= 0 and fx.punct_is(j, "{"):
                    close = fx.partner[j]
                    if close is not None:
                        split_segments(j, close)
                    break
                j += 1
            i += 1
            continue
        i += 1
    return out


def resolve(bindings, name, pos):
    before = None
    after = None
    for b in bindings:
        if b[0] != name:
            continue
        if b[2] <= pos:
            if before is None or b[2] >= before[2]:
                before = b
        elif after is None or b[2] < after[2]:
            after = b
    return before if before is not None else after


def hash_typed(b):
    return contains_word(b[1], "HashMap") or contains_word(b[1], "HashSet")


# --------------------------------------------------------------------------
# Per-file analysis.
# --------------------------------------------------------------------------

def design_family(spec):
    out = []
    for ch in spec:
        if "a" <= ch <= "z":
            out.append(ch)
        else:
            break
    return "".join(out)


def str_content(text):
    a = text.find('"')
    b = text.rfind('"')
    if a < 0 or b <= a:
        return ""
    return text[a + 1:b]


def analyze_file(path, src):
    fx = Fx(src)
    profile = profile_for(path)

    def on(rule):
        scope = rule_scope(profile, rule)
        return scope is not None and in_scope(path, scope)

    marker_problems = []
    markers = []  # (line, target, rules, reason)
    for (line, text) in fx.comments:
        parsed = parse_marker(text)
        if parsed is None:
            continue
        if parsed[0] == "err":
            marker_problems.append({"path": path, "line": line, "message": parsed[1]})
        else:
            target = line + 1 if not fx.line_has_code[line] else line
            markers.append((line, target, parsed[1], parsed[2]))
    allow = {}
    for (_line, target, rules, reason) in markers:
        entry = allow.setdefault(target, {})
        for r in rules:
            entry[r] = reason

    n = len(fx.toks)
    cands = []  # (pos, line, rule, message)

    def push(i, rule, msg):
        cands.append((fx.pos(i), fx.line(i), rule, msg))

    bindings = collect_bindings(fx) if on("D1v2") else []
    d1v2_seen = set()

    def d1v2_site(i, name, ty):
        key = (fx.line(i), name)
        if key in d1v2_seen:
            return
        d1v2_seen.add(key)
        cands.append((
            fx.pos(i), fx.line(i), "D1v2",
            "iteration over hash-ordered binding `%s` (type `%s`) leaks "
            "per-process order into a trajectory/artifact module (use "
            "BTreeMap/BTreeSet, or restructure to keyed lookup)" % (name, ty),
        ))

    for i in range(n):
        if fx.mask[i]:
            continue
        kind = fx.kind(i)
        if kind == IDENT:
            t = fx.text(i)
            if on("D1") and t in ("HashMap", "HashSet"):
                push(i, "D1",
                     "hash-ordered container `%s` in a trajectory/artifact module "
                     "(iteration order leaks; use BTreeMap/BTreeSet or annotate a "
                     "lookup-only use)" % t)
            if on("D2"):
                pat = None
                if (t == "Instant" and fx.punct_is(i + 1, ":")
                        and fx.punct_is(i + 2, ":") and fx.ident_is(i + 3, "now")):
                    pat = "Instant::now"
                elif t == "SystemTime":
                    pat = "SystemTime"
                elif (t == "std" and fx.punct_is(i + 1, ":")
                        and fx.punct_is(i + 2, ":") and fx.ident_is(i + 3, "time")):
                    pat = "std::time"
                if pat is not None:
                    push(i, "D2",
                         "wall-clock `%s` in a step-math module (breaks bit-identical "
                         "replay; move timing out of the step path or annotate "
                         "telemetry-only use)" % pat)
            if (t == "thread" and fx.punct_is(i + 1, ":") and fx.punct_is(i + 2, ":")
                    and fx.ident_is(i + 3, "spawn")
                    and not in_scope(path, D3_SPAWN_EXEMPT)):
                push(i, "D3",
                     "raw `thread::spawn` outside parallel/ (use "
                     "parallel::par_map / par_chunks_mut, which keep results "
                     "thread-count invariant)")
            if on("D3") and i > 0 and fx.punct_is(i - 1, "."):
                if t == "sum":
                    turbofish = (
                        fx.punct_is(i + 1, ":") and fx.punct_is(i + 2, ":")
                        and fx.punct_is(i + 3, "<")
                        and (fx.ident_is(i + 4, "f32") or fx.ident_is(i + 4, "f64"))
                    )
                    bare = (
                        fx.punct_is(i + 1, "(") and fx.punct_is(i + 2, ")")
                        and fx.float_evidence(fx.stmt_start(i), i)
                    )
                    if turbofish or bare:
                        push(i - 1, "D3",
                             "float `.sum()` reduction in the numeric spine (must be "
                             "sequential in a fixed order — annotate why this one "
                             "is, or route through the k-ordered kernels)")
                if t == "fold" and fx.punct_is(i + 1, "("):
                    close = fx.partner[i + 1]
                    close = n if close is None else close
                    if fx.float_evidence(i + 2, close):
                        push(i - 1, "D3",
                             "float-accumulator `.fold(..)` reduction in the numeric "
                             "spine (order-sensitive; annotate or restructure)")
            if on("P1"):
                if i > 0 and fx.punct_is(i - 1, "."):
                    if t == "unwrap" and fx.punct_is(i + 1, "(") and fx.punct_is(i + 2, ")"):
                        push(i - 1, "P1",
                             "`unwrap()` in the resilience spine (typed errors are the "
                             "contract here: a panic turns a recoverable fault into an "
                             "abort)")
                    if t == "expect" and fx.punct_is(i + 1, "("):
                        push(i - 1, "P1",
                             "`expect(` in the resilience spine (typed errors are the "
                             "contract here: a panic turns a recoverable fault into an "
                             "abort)")
                if (t in ("panic", "unreachable", "todo", "unimplemented")
                        and fx.punct_is(i + 1, "!") and fx.pos(i + 1) == fx.end(i)):
                    push(i, "P1",
                         "`%s!` in the resilience spine (raise a typed error instead)" % t)
            if (on("S1") and t == "as" and i + 1 < n and fx.kind(i + 1) == IDENT
                    and fx.text(i + 1) in INT_TYPES
                    and fx.float_evidence(fx.stmt_start(i), i)):
                push(i, "S1",
                     "float->int `as %s` cast in a mult/ decomposition path (silently "
                     "saturates/truncates; use the checked helpers in mult::cast)"
                     % fx.text(i + 1))
            if on("U1") and t == "unsafe":
                l = fx.line(i)

                def has_safety(line):
                    return any(cl == line and "SAFETY:" in c for (cl, c) in fx.comments)

                ok = has_safety(l)
                if not ok:
                    k = l - 1
                    while k >= 1 and not fx.line_has_code[k]:
                        if not any(cl == k for (cl, _c) in fx.comments):
                            break
                        if has_safety(k):
                            ok = True
                            break
                        k -= 1
                if not ok:
                    push(i, "U1",
                         "`unsafe` without an immediately preceding `// SAFETY:` "
                         "comment (state the proof obligation the compiler cannot "
                         "check)")
            if on("D1v2"):
                if t == "for" and not fx.punct_is(i + 1, "<"):
                    depth = 0
                    j = i + 1
                    in_idx = None
                    while j < n:
                        if fx.kind(j) == PUNCT:
                            tj = fx.text(j)
                            if tj in "([":
                                depth += 1
                            elif tj in ")]":
                                depth -= 1
                            elif tj in "{;" and depth == 0:
                                break
                        elif depth == 0 and fx.ident_is(j, "in"):
                            in_idx = j
                            break
                        j += 1
                    if in_idx is not None:
                        depth = 0
                        j = in_idx + 1
                        while j < n:
                            if fx.kind(j) == PUNCT:
                                tj = fx.text(j)
                                if tj in "([":
                                    depth += 1
                                elif tj in ")]":
                                    depth -= 1
                                elif tj == "{" and depth == 0:
                                    break
                            elif fx.kind(j) == IDENT:
                                name = fx.text(j)
                                dotted = j > 0 and fx.punct_is(j - 1, ".")
                                self_field = dotted and fx.ident_is(j - 2, "self")
                                if name != "self" and (not dotted or self_field):
                                    b = resolve(bindings, name, fx.pos(j))
                                    if b is not None and hash_typed(b):
                                        d1v2_site(j, name, b[1])
                            j += 1
                if (t in ITER_METHODS and i > 0 and fx.punct_is(i - 1, ".")
                        and fx.punct_is(i + 1, "(") and i >= 2 and fx.kind(i - 2) == IDENT):
                    name = fx.text(i - 2)
                    plain = i < 3 or not fx.punct_is(i - 3, ".")
                    self_field = (not plain) and i >= 4 and fx.ident_is(i - 4, "self")
                    if name != "self" and (plain or self_field):
                        b = resolve(bindings, name, fx.pos(i - 2))
                        if b is not None and hash_typed(b):
                            d1v2_site(i - 2, name, b[1])
        if kind == PUNCT and on("P2") and fx.punct_is(i, "[") and i > 0:
            p = i - 1
            pk = fx.kind(p)
            if pk == IDENT:
                indexy = fx.text(p) not in NON_INDEX_KEYWORDS
            elif pk == PUNCT:
                indexy = fx.text(p) in (")", "]", "?")
            else:
                indexy = False
            if indexy:
                push(i, "P2",
                     "panicking slice/array index `[..]` in the resilience spine (a "
                     "short or corrupt buffer must surface as a typed fault, not an "
                     "abort; use .get()/.get_mut())")

    # C1 facts.
    registrations = []
    if on("C1"):
        for i in range(n):
            if not (fx.ident_is(i, "fn") and fx.ident_is(i + 1, "simd_kernel")) or fx.mask[i]:
                continue
            body_open = None
            j = i + 2
            while j < n:
                if fx.punct_is(j, "{"):
                    body_open = j
                    break
                if fx.punct_is(j, ";"):
                    break
                j += 1
            if body_open is None:
                continue
            close = fx.partner[body_open]
            close = n if close is None else close
            for k in range(body_open, close):
                ke = fx.text(k)
                if (fx.kind(k) == IDENT and ke in ("UnsignedKernel", "SignedKernel")
                        and fx.punct_is(k + 1, ":") and fx.punct_is(k + 2, ":")
                        and k + 3 < n and fx.kind(k + 3) == IDENT):
                    fam = KERNEL_FAMILIES.get((ke, fx.text(k + 3)))
                    if fam is not None:
                        registrations.append((fam, fx.line(i)))
                        break
    norm = path.replace("\\", "/")
    is_parity_file = norm.rsplit("/", 1)[-1] == "simd_parity.rs"
    parity_families = set()
    if is_parity_file:
        for i in range(n):
            if not (fx.ident_is(i, "DESIGNS") or fx.ident_is(i, "SIGNED_DESIGNS")):
                continue
            depth = 0
            j = i + 1
            while j < n:
                if fx.kind(j) == PUNCT:
                    tj = fx.text(j)
                    if tj in "([{":
                        depth += 1
                    elif tj in ")]}":
                        depth -= 1
                    elif tj == ";" and depth == 0:
                        break
                elif fx.kind(j) == STR:
                    fam = design_family(str_content(fx.text(j)))
                    if fam:
                        parity_families.add(fam)
                j += 1
    is_bench_file = in_scope(path, ["benches"])
    bench_families = set()
    if is_bench_file:
        for i in range(n):
            if fx.kind(i) == STR:
                fam = design_family(str_content(fx.text(i)))
                if fam:
                    bench_families.add(fam)

    cands.sort(key=lambda c: (c[0], c[2]))
    violations = []
    suppressions = []
    used = set()
    for (pos, line, rule, message) in cands:
        reason = allow.get(line, {}).get(rule)
        if reason is not None:
            used.add((line, rule))
            suppressions.append(
                {"rule": rule, "path": path, "line": line, "reason": reason})
            continue
        violations.append(
            {"rule": rule, "path": path, "line": line, "message": message})

    return {
        "path": path,
        "violations": violations,
        "suppressions": suppressions,
        "marker_problems": marker_problems,
        "markers": markers,
        "used": used,
        "allow": allow,
        "registrations": registrations,
        "parity_seen": is_parity_file,
        "parity_families": parity_families,
        "bench_seen": is_bench_file,
        "bench_families": bench_families,
    }


# --------------------------------------------------------------------------
# Finalize + scan entry points.
# --------------------------------------------------------------------------

def rule_index(rule):
    try:
        return RULE_IDS.index(rule)
    except ValueError:
        return len(RULE_IDS)


def finalize(files):
    parity_seen = any(f["parity_seen"] for f in files)
    bench_seen = any(f["bench_seen"] for f in files)
    parity = set()
    bench = set()
    for f in files:
        parity |= f["parity_families"]
        bench |= f["bench_families"]
    report = {
        "files_scanned": len(files),
        "violations": [],
        "suppressions": [],
        "marker_problems": [],
        "stale_markers": [],
    }
    for f in files:
        for (family, line) in f["registrations"]:
            gaps = []
            if parity_seen and family not in parity:
                gaps.append("the simd_parity.rs design lists")
            if bench_seen and family not in bench:
                gaps.append("a named bench row")
            if not gaps:
                continue
            message = (
                "design family `%s` registers a simd_kernel() but is missing "
                "from %s (the scalar<->SIMD bit-identity pin)"
                % (family, " and ".join(gaps))
            )
            reason = f["allow"].get(line, {}).get("C1")
            if reason is not None:
                f["used"].add((line, "C1"))
                f["suppressions"].append(
                    {"rule": "C1", "path": f["path"], "line": line, "reason": reason})
            else:
                f["violations"].append(
                    {"rule": "C1", "path": f["path"], "line": line, "message": message})
        for (line, target, rules, _reason) in f["markers"]:
            for r in rules:
                if (target, r) not in f["used"]:
                    report["stale_markers"].append({
                        "path": f["path"], "line": line,
                        "message": "stale marker: allow(%s) suppressed nothing" % r,
                    })
        report["violations"].extend(f["violations"])
        report["suppressions"].extend(f["suppressions"])
        report["marker_problems"].extend(f["marker_problems"])
    report["violations"].sort(
        key=lambda v: (v["path"], v["line"], rule_index(v["rule"]), v["message"]))
    report["suppressions"].sort(key=lambda s: (s["path"], s["line"], s["rule"]))
    report["marker_problems"].sort(key=lambda p: (p["path"], p["line"]))
    report["stale_markers"].sort(key=lambda p: (p["path"], p["line"]))
    return report


def failed(report):
    return bool(report["violations"]) or bool(report["marker_problems"])


def scan_source(path, src):
    return finalize([analyze_file(path, src)])


def scan_sources(files):
    return finalize([analyze_file(p, s) for (p, s) in files])


def collect_rs_files(path, out):
    if os.path.isfile(path):
        if path.endswith(".rs"):
            out.append(path)
        return
    entries = sorted(os.path.join(path, e) for e in os.listdir(path))
    for e in entries:
        if os.path.isdir(e):
            collect_rs_files(e, out)
        elif e.endswith(".rs"):
            out.append(e)


def scan_paths(paths):
    files = []
    for p in paths:
        batch = []
        collect_rs_files(p, batch)
        batch.sort()
        files.extend(batch)
    analyses = []
    for f in files:
        with open(f, "r", encoding="utf-8") as fh:
            src = fh.read()
        analyses.append(analyze_file(f.replace("\\", "/"), src))
    return finalize(analyses)


# --------------------------------------------------------------------------
# JSON output (byte-identical to `detlint --json`).
# --------------------------------------------------------------------------

def json_escape(s):
    out = []
    for c in s:
        if c == '"':
            out.append('\\"')
        elif c == "\\":
            out.append("\\\\")
        elif c == "\n":
            out.append("\\n")
        elif c == "\r":
            out.append("\\r")
        elif c == "\t":
            out.append("\\t")
        elif ord(c) < 0x20:
            out.append("\\u%04x" % ord(c))
        else:
            out.append(c)
    return "".join(out)


def report_json(report, ok):
    vs = ",".join(
        '{"rule":"%s","path":"%s","line":%d,"message":"%s"}'
        % (v["rule"], json_escape(v["path"]), v["line"], json_escape(v["message"]))
        for v in report["violations"])
    ss = ",".join(
        '{"rule":"%s","path":"%s","line":%d,"reason":"%s"}'
        % (json_escape(s["rule"]), json_escape(s["path"]), s["line"],
           json_escape(s["reason"]))
        for s in report["suppressions"])
    probs = ",".join(
        '{"path":"%s","line":%d,"message":"%s"}'
        % (json_escape(p["path"]), p["line"], json_escape(p["message"]))
        for p in report["marker_problems"])
    stale = ",".join(
        '{"path":"%s","line":%d,"message":"%s"}'
        % (json_escape(p["path"]), p["line"], json_escape(p["message"]))
        for p in report["stale_markers"])
    return (
        '{"files_scanned":%d,"violations":[%s],"grandfathered":[],'
        '"suppressions":[%s],"marker_problems":[%s],"stale_markers":[%s],"ok":%s}'
        % (report["files_scanned"], vs, ss, probs, stale,
           "true" if ok else "false"))


def print_report_text(report):
    for v in report["violations"]:
        print("%s:%d: [%s] %s" % (v["path"], v["line"], v["rule"], v["message"]))
    for p in report["marker_problems"]:
        print("%s:%d: [marker] %s" % (p["path"], p["line"], p["message"]))
    for s in report["stale_markers"]:
        print("%s:%d: [stale] %s" % (s["path"], s["line"], s["message"]))
    print("detlint-mirror: %d file(s), %d violation(s), %d suppression(s), "
          "%d marker problem(s), %d stale marker(s)"
          % (report["files_scanned"], len(report["violations"]),
             len(report["suppressions"]), len(report["marker_problems"]),
             len(report["stale_markers"])))


# --------------------------------------------------------------------------
# Validation suite (default mode).
# --------------------------------------------------------------------------

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(
    REPO_ROOT, "rust", "analyzers", "detlint", "fixtures").replace("\\", "/")

TREE_SCAN_SET = [
    "rust/src",
    "rust/benches",
    "rust/tests",
    "examples",
    "rust/analyzers/detlint/src",
    "rust/analyzers/detlint/tests",
]

_failures = []


def check(name, cond, detail=""):
    if cond:
        print("ok   %s" % name)
    else:
        print("FAIL %s%s" % (name, (" — " + detail) if detail else ""))
        _failures.append(name)


def rules_of(report):
    return [v["rule"] for v in report["violations"]]


def scan_fixture_file(rel):
    path = FIXTURES + "/" + rel
    with open(path, "r", encoding="utf-8") as fh:
        return scan_source(path, fh.read())


def scan_fixture_dir(rel):
    return scan_paths([os.path.join(FIXTURES, rel) if rel else FIXTURES])


def run_fixture_checks():
    singles = [
        ("bad/mult/d1_hash_iteration.rs", "D1"),
        ("bad/mult/d1v2_iteration_site.rs", "D1v2"),
        ("bad/mult/s1_unchecked_cast.rs", "S1"),
        ("bad/runtime/native/d2_wall_clock.rs", "D2"),
        ("bad/runtime/native/d3_unordered_reduction.rs", "D3"),
        ("bad/checkpoint/p2_slice_index.rs", "P2"),
        ("bad/runtime/u1_unsafe_no_safety.rs", "U1"),
    ]
    for rel, rule in singles:
        r = scan_fixture_file(rel)
        check("fixture %s fires %s exactly once" % (rel, rule),
              rules_of(r) == [rule], repr(rules_of(r)))
    r = scan_fixture_file("bad/checkpoint/p1_panic_in_recovery.rs")
    check("P1 fixture crossfires P2 on the same line",
          sorted(rules_of(r)) == ["P1", "P2"]
          and len({v["line"] for v in r["violations"]}) == 1,
          repr(r["violations"]))
    allowed = [
        ("allowed/mult/allow_marker.rs", 2),
        ("allowed/mult/d1v2_allowed.rs", 2),
        ("allowed/checkpoint/p2_allowed.rs", 1),
        ("allowed/runtime/u1_allowed.rs", 1),
    ]
    for rel, n_sup in allowed:
        r = scan_fixture_file(rel)
        check("fixture %s suppresses x%d, no stale" % (rel, n_sup),
              not r["violations"] and len(r["suppressions"]) == n_sup
              and not r["stale_markers"],
              repr((rules_of(r), r["suppressions"], r["stale_markers"])))
    for rel in [
        "clean/mult/ordered_clean.rs",
        "clean/mult/d1v2_btree_iter.rs",
        "clean/checkpoint/p2_get_checked.rs",
        "clean/runtime/u1_safety_comment.rs",
    ]:
        r = scan_fixture_file(rel)
        check("fixture %s is silent" % rel,
              not r["violations"] and not r["suppressions"]
              and not r["stale_markers"], repr(rules_of(r)))

    bad = scan_fixture_dir("c1/bad")
    check("c1/bad fires C1 once with both gaps",
          rules_of(bad) == ["C1"]
          and "mitchell" in bad["violations"][0]["message"]
          and "design lists" in bad["violations"][0]["message"]
          and "named bench row" in bad["violations"][0]["message"],
          repr(bad["violations"]))
    allowed_c1 = scan_fixture_dir("c1/allowed")
    check("c1/allowed suppresses C1, no stale",
          not allowed_c1["violations"]
          and [s["rule"] for s in allowed_c1["suppressions"]] == ["C1"]
          and not allowed_c1["stale_markers"],
          repr((allowed_c1["violations"], allowed_c1["suppressions"],
                allowed_c1["stale_markers"])))
    clean_c1 = scan_fixture_dir("c1/clean")
    check("c1/clean is silent",
          not clean_c1["violations"] and not clean_c1["suppressions"],
          repr(clean_c1["violations"]))

    corpus = scan_fixture_dir("")
    check("whole corpus: 25 files, 10 violations, 8 suppressions, 0 problems, 0 stale",
          corpus["files_scanned"] == 25 and len(corpus["violations"]) == 10
          and len(corpus["suppressions"]) == 8
          and not corpus["marker_problems"] and not corpus["stale_markers"],
          repr((corpus["files_scanned"], len(corpus["violations"]),
                len(corpus["suppressions"]), corpus["marker_problems"],
                corpus["stale_markers"])))


def run_scenario_checks():
    """Ported lib.rs unit-test scenarios — engine semantics, no files."""
    r = scan_source("rust/src/mult/mod.rs",
                    '// HashMap in a comment is fine\n'
                    'fn f() -> &\'static str { "HashMap" }\n')
    check("comments and strings are not code", not r["violations"],
          repr(rules_of(r)))

    r = scan_source(
        "rust/src/mult/mod.rs",
        'fn f() { let s = r#"HashMap"#; let c = \'{\'; '
        'let m: std::collections::HashMap<u8, u8> = Default::default(); '
        'let _ = (s, c, m); }\n')
    check("raw strings and char literals stay out of the token stream",
          rules_of(r) == ["D1"] and r["violations"][0]["line"] == 1,
          repr(r["violations"]))

    r = scan_source("rust/src/checkpoint/mod.rs",
                    "pub fn first(bytes: &[u8]) -> u8 { bytes[0] }\n")
    check("P2 fires on index expressions", rules_of(r) == ["P2"], repr(rules_of(r)))
    r = scan_source(
        "rust/src/checkpoint/mod.rs",
        "#[derive(Clone)]\npub struct B { v: [u8; 4] }\n"
        "pub fn first(bytes: &[u8]) -> Option<u8> { bytes.get(0).copied() }\n")
    check("P2 ignores type and attribute brackets", not r["violations"],
          repr(rules_of(r)))
    r = scan_source("rust/src/checkpoint/mod.rs",
                    "fn f(rows: &[Vec<u8>]) -> u8 { rows[0][1] }\n")
    check("P2 fires per chained index", rules_of(r) == ["P2", "P2"], repr(rules_of(r)))

    src = ("use std::collections::HashMap;\n"
           "fn f(m: &HashMap<u32, u64>) -> u64 {\n"
           "    let mut acc = 0u64;\n"
           "    for (_k, v) in m.iter() {\n"
           "        acc += *v;\n"
           "    }\n"
           "    acc + m.get(&0).copied().unwrap_or(0)\n"
           "}\n")
    r = scan_source("rust/src/runtime/engine.rs", src)
    d1v2 = [v for v in r["violations"] if v["rule"] == "D1v2"]
    check("D1v2 fires once at the iteration site",
          len(d1v2) == 1 and d1v2[0]["line"] == 4, repr(r["violations"]))

    src = ("use std::collections::HashMap;\n"
           "// detlint: allow(D1) -- scenario: lookup table under test\n"
           "struct C { map: HashMap<u32, u64> }\n"
           "impl C {\n"
           "    fn leak(&self) -> u64 { self.map.values().sum::<u64>() }\n"
           "}\n")
    r = scan_source("rust/src/runtime/engine.rs", src)
    d1v2 = [v for v in r["violations"] if v["rule"] == "D1v2"]
    check("D1v2 tracks struct fields through self",
          len(d1v2) == 1 and d1v2[0]["line"] == 5, repr(r["violations"]))

    r = scan_source("rust/src/runtime/mod.rs",
                    "fn f(p: *const u8) -> u8 { unsafe { *p } }\n")
    check("U1 fires without a SAFETY comment", rules_of(r) == ["U1"],
          repr(rules_of(r)))
    r = scan_source(
        "rust/src/runtime/mod.rs",
        "fn f(p: *const u8) -> u8 {\n"
        "    // SAFETY: caller keeps p valid for reads;\n"
        "    // the deref copies one byte.\n"
        "    unsafe { *p }\n"
        "}\n")
    check("U1 accepts contiguous comment lines above", not r["violations"],
          repr(rules_of(r)))
    r = scan_source(
        "rust/src/runtime/mod.rs",
        "fn f(p: *const u8) -> u8 {\n"
        "    // SAFETY: too far away\n"
        "\n"
        "    unsafe { *p }\n"
        "}\n")
    check("U1 rejects a blank-line gap", rules_of(r) == ["U1"], repr(rules_of(r)))

    reg = ("pub fn simd_kernel(&self) -> Option<K> "
           "{ Some(UnsignedKernel::Mitchell { bits: 8 }) }\n")
    r = scan_sources([
        ("rust/src/mult/mitchell.rs", reg),
        ("rust/tests/simd_parity.rs",
         'const DESIGNS: &[&str] = &["exact", "drum6"];\n'),
        ("rust/benches/multipliers.rs",
         'fn rows() -> Vec<&\'static str> { vec!["exact", "drum6"] }\n'),
    ])
    c1 = [v for v in r["violations"] if v["rule"] == "C1"]
    check("C1 fires cross-file for an unpinned family",
          len(c1) == 1 and "mitchell" in c1[0]["message"], repr(r["violations"]))
    r = scan_sources([
        ("rust/src/mult/mitchell.rs", reg),
        ("rust/tests/simd_parity.rs",
         'const DESIGNS: &[&str] = &["exact", "mitchell"];\n'),
        ("rust/benches/multipliers.rs",
         'fn rows() -> Vec<&\'static str> { vec!["exact", "mitchell"] }\n'),
    ])
    check("C1 is quiet for a pinned family", not r["violations"],
          repr(rules_of(r)))
    r = scan_source("rust/src/mult/mitchell.rs", reg)
    check("C1 needs parity/bench facts in the scan set", not r["violations"],
          repr(rules_of(r)))

    r = scan_source("rust/tests/checkpoint_suite.rs",
                    "fn f(x: Option<u8>) -> u8 { x.unwrap() }\n")
    check("tests profile drops P1", not r["violations"], repr(rules_of(r)))
    r = scan_source("rust/tests/misc.rs", "use std::collections::HashMap;\n")
    check("tests profile keeps D1 everywhere", rules_of(r) == ["D1"],
          repr(rules_of(r)))
    check("fixtures profile precedence",
          profile_for("rust/analyzers/detlint/fixtures/bad/mult/x.rs") == DEFAULT
          and profile_for("rust/analyzers/detlint/src/lib.rs") == ANALYZER
          and profile_for("rust/tests/misc.rs") == TESTS)

    r = scan_source("rust/src/mult/mod.rs",
                    "// detlint: allow(D9) -- no such rule\n"
                    "// detlint: allow(D1)\n"
                    "// detlint: deny(D1) -- wrong verb\n"
                    "fn f() {}\n")
    check("malformed markers are problems", len(r["marker_problems"]) == 3,
          repr(r["marker_problems"]))
    r = scan_source("rust/src/mult/mod.rs",
                    "// detlint: allow(D1) -- nothing here anymore\nfn f() {}\n")
    check("stale markers warn", not r["violations"]
          and len(r["stale_markers"]) == 1 and not failed(r),
          repr(r["stale_markers"]))


def run_tree_check():
    paths = [p for p in TREE_SCAN_SET
             if os.path.exists(os.path.join(REPO_ROOT, p))]
    cwd = os.getcwd()
    os.chdir(REPO_ROOT)
    try:
        report = scan_paths(paths)
    finally:
        os.chdir(cwd)
    ok = (not report["violations"] and not report["marker_problems"]
          and not report["stale_markers"])
    check("tree scan over %s is clean (strict-stale)" % " ".join(paths), ok, "")
    if not ok:
        print_report_text(report)
    else:
        print("     (%d files, %d audited suppressions)"
              % (report["files_scanned"], len(report["suppressions"])))


def main(argv):
    if argv and argv[0] == "--scan":
        as_json = False
        strict_stale = False
        paths = []
        for a in argv[1:]:
            if a == "--json":
                as_json = True
            elif a == "--strict-stale":
                strict_stale = True
            elif a.startswith("-"):
                sys.stderr.write("check_detlint_rules: unknown flag `%s`\n" % a)
                return 2
            else:
                paths.append(a)
        if not paths:
            sys.stderr.write(
                "usage: check_detlint_rules.py --scan [--json] [--strict-stale] <path>...\n")
            return 2
        report = scan_paths(paths)
        bad = failed(report) or (strict_stale and bool(report["stale_markers"]))
        if as_json:
            print(report_json(report, not bad))
        else:
            print_report_text(report)
        return 1 if bad else 0
    if argv:
        sys.stderr.write(
            "usage: check_detlint_rules.py            # run the validation suite\n"
            "       check_detlint_rules.py --scan [--json] [--strict-stale] <path>...\n")
        return 2
    run_fixture_checks()
    run_scenario_checks()
    run_tree_check()
    if _failures:
        print("\n%d check(s) FAILED" % len(_failures))
        return 1
    print("\nall detlint mirror checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
