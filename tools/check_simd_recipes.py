#!/usr/bin/env python3
"""Cross-validation of the branchless SIMD kernel recipes in
`rust/src/mult/simd/` against straight transcriptions of the scalar
Rust designs.

No Rust toolchain is available in the authoring container, so this
script is the executable check that the *algorithms* behind the vector
kernels are equivalent to the scalar ones before `tests/simd_parity.rs`
can pin the compiled artifacts. Two implementations of every kernel are
kept deliberately different in style:

* ``scalar_*`` — line-by-line transcriptions of the Rust scalar code
  (``Drum::mul``, ``Mitchell::mul``, ``Booth::mul``, ``renorm`` ...);
* ``vector_*`` — the branchless select/mask formulas the `std::simd`
  kernels use, evaluated lane-wise (including the dummy-lane handling
  the GEMM chain kernel relies on).

They are compared on exhaustive edge operands plus randomized sweeps,
and the k-chain accumulation argument (full term list with ``+0.0``
placeholders == compact list with flushed terms skipped) is checked on
f32 chains seeded with inf/NaN/signed-zero/subnormal operands.

Run: ``python3 tools/check_simd_recipes.py`` (exit 0 == all recipes
equivalent).
"""

import random
import sys

import numpy as np

M32 = 0xFFFFFFFF
M64 = 0xFFFFFFFFFFFFFFFF
FRAC_BITS = 32
EXP_NONFINITE = 2**31 - 1  # i32::MAX sentinel from prepared.rs


def u32(v):
    return v & M32


def u64(v):
    return v & M64


def i32(v):
    v &= M32
    return v - (1 << 32) if v >= (1 << 31) else v


def i64(v):
    v &= M64
    return v - (1 << 64) if v >= (1 << 63) else v


def lz32(v):
    return 32 - v.bit_length() if v else 32


def lz64(v):
    return 64 - v.bit_length() if v else 64


# --- f32 helpers (exact IEEE single-precision via struct) -------------


def f32_from_bits(b):
    return np.uint32(b).view(np.float32)


def f32_to_bits(x):
    return int(np.float32(x).view(np.uint32))


def f32_add(x, y):
    # numpy float32 arithmetic is IEEE round-to-nearest-even with
    # overflow to inf — the same as Rust f32 `+`.
    with np.errstate(all="ignore"):
        return np.float32(x) + np.float32(y)


# --- scalar transcriptions (the Rust `mul` bodies) --------------------


def scalar_drum_reduce(v, k):
    if v == 0:
        return (0, 0)
    msb = 31 - lz32(v)
    if msb < k:
        return (v, 0)
    shift = msb + 1 - k
    return ((v >> shift) | 1, shift)


def scalar_drum(k, a, b):
    ta, sa = scalar_drum_reduce(a, k)
    tb, sb = scalar_drum_reduce(b, k)
    return u64((ta * tb) << (sa + sb))


def scalar_trunc(k, a, b):
    mask = u32(M32 << k)
    return u64((a & mask) * (b & mask))


def scalar_log2_fixed(v):
    assert v > 0
    msb = 31 - lz32(v)
    frac = u64(v << (FRAC_BITS - msb)) & ((1 << FRAC_BITS) - 1)
    return (msb << FRAC_BITS) | frac


def scalar_antilog_fixed(l):
    intp = l >> FRAC_BITS
    frac = l & ((1 << FRAC_BITS) - 1)
    mantissa = (1 << FRAC_BITS) | frac
    if intp >= FRAC_BITS:
        return u64(mantissa << (intp - FRAC_BITS))
    return mantissa >> (FRAC_BITS - intp)


def scalar_mitchell(a, b):
    if a == 0 or b == 0:
        return 0
    return scalar_antilog_fixed(scalar_log2_fixed(a) + scalar_log2_fixed(b))


def scalar_sdrum(k, a, b):
    mag = scalar_drum(k, abs(a), abs(b))
    assert mag <= (1 << 63) - 1
    return -mag if (a < 0) != (b < 0) else mag


def scalar_booth(k, a, b):
    bits = u32(b)  # two's-complement bit pattern, zero-extended
    acc = 0
    prev = 0
    for idx in range(16):
        b0 = (bits >> (2 * idx)) & 1
        b1 = (bits >> (2 * idx + 1)) & 1
        d = (b0 + prev) - 2 * b1
        prev = b1
        if d != 0:
            pp = i64(u64(d * a) << (2 * idx))
            acc = i64(acc + ((pp >> k) << k))  # Python >> floors == arithmetic
    return acc


def scalar_lut_flat(table, bits, a, b):
    # LutMultiplier on mantissa-domain operands (>= 2^23): reduce is the
    # constant shift 24 - bits, then shift_saturating by the sum.
    shift = 24 - bits
    v = table[((a >> shift) << bits) | (b >> shift)]
    total = 2 * shift
    if v == 0:
        return 0
    if lz64(v) >= total:
        return u64(v << total)
    return M64


def scalar_slut_flat(table, bits, half, a, b):
    # SignedLut on signed mantissa operands (|v| in [2^23, 2^24)):
    # always out of domain, msb == 23, shift == 25 - bits.
    shift = 25 - bits

    def reduce(v):
        mag = abs(v)
        red = mag >> shift
        return -red if v < 0 else red

    ia, ib = reduce(a), reduce(b)
    v = table[((ia + half) << bits) | (ib + half)]
    total = 2 * shift
    if v == 0 or total == 0:
        return v
    if lz64(abs(v)) > total:
        return i64(v << total)
    return -(1 << 63) if v < 0 else (1 << 63) - 1


def scalar_renorm(sign, ex, ey, p):
    """rust matmul.rs renorm(), bit-exact; returns f32 bits."""
    if p == 0:
        return sign << 31
    q = 63 - lz64(p)
    if q > 23:
        mant = u32(p >> (q - 23))
    else:
        mant = u32(p << (23 - q))
    er = ex + ey + q - 173
    if er >= 255:
        return (sign << 31) | 0x7F800000
    if er <= 0:
        return sign << 31
    return (sign << 31) | (u32(er) << 23) | (mant & 0x007FFFFF)


# --- vector (branchless select/mask) recipes, lane-wise ----------------


def select(m, t, f):
    return t if m else f


def vector_drum_reduce(v, k):
    nz = v != 0
    vv = select(nz, v, 1)
    msb = 31 - lz32(vv)
    big = msb >= k
    shift = select(big, msb + 1 - k, 0)
    t = select(big, (vv >> shift) | 1, vv)
    return (select(nz, t, 0), shift)


def vector_drum(k, a, b):
    ta, sa = vector_drum_reduce(a, k)
    tb, sb = vector_drum_reduce(b, k)
    return u64((ta * tb) << (sa + sb))


def vector_trunc(k, a, b):
    mask = u32(M32 << k)
    return u64((a & mask) * (b & mask))


def vector_mitchell(a, b):
    one_a = select(a != 0, a, 1)
    one_b = select(b != 0, b, 1)

    def log2v(v):
        msb = 31 - lz32(v)
        frac = u64(v << (FRAC_BITS - msb)) & ((1 << FRAC_BITS) - 1)
        return (msb << FRAC_BITS) | frac

    l = log2v(one_a) + log2v(one_b)
    intp = l >> FRAC_BITS
    frac = l & ((1 << FRAC_BITS) - 1)
    mant = (1 << FRAC_BITS) | frac
    ge = intp >= FRAC_BITS
    shl = select(ge, intp - FRAC_BITS, 0)
    shr = select(ge, 0, FRAC_BITS - intp)
    p = u64(mant << shl) >> shr
    return select((a != 0) and (b != 0), p, 0)


def vector_sdrum(k, a, b):
    # sign masks: arithmetic >> 31 of the i32 lanes
    sa = -1 if a < 0 else 0
    sb = -1 if b < 0 else 0
    mag_a = u32((a ^ sa) - sa)  # wrapping conditional negate, bit cast
    mag_b = u32((b ^ sb) - sb)
    mag = vector_drum(k, mag_a, mag_b)
    neg = i64(sa ^ sb)  # 0 or -1, sign-extended
    return i64((i64(mag) ^ neg) - neg)


def vector_booth(k, a, b):
    bits = u32(b)
    acc = 0
    prev = 0
    for idx in range(16):
        b0 = (bits >> (2 * idx)) & 1
        b1 = (bits >> (2 * idx + 1)) & 1
        d = (b0 + prev) - 2 * b1
        prev = b1
        # Unconditional lane math: d == 0 contributes 0.
        pp = i64(u64(d * a) << (2 * idx))
        acc = i64(acc + ((pp >> k) << k))
    return acc


def vector_renorm(sign, esum, p):
    """The select-ordered vector renorm; returns f32 bits."""
    pz = p == 0
    pp = select(pz, 1, p)
    q = 63 - lz64(pp)
    gt = q > 23
    shr = select(gt, q - 23, 0)
    mant_hi = u32(pp >> shr)
    shl = select(gt, 0, 23 - q)
    mant_lo = u32(u32(pp) << shl)
    mant = select(gt, mant_hi, mant_lo)
    er = esum + q - 173
    sign31 = sign << 31
    packed = sign31 | (u32(er) << 23) | (mant & 0x007FFFFF)
    bits = packed
    bits = select(er >= 255, sign31 | 0x7F800000, bits)
    bits = select(er <= 0, sign31, bits)
    bits = select(pz, sign31, bits)
    return bits


def vector_lut_flat(table, bits, a, b):
    shift = 24 - bits
    idx = ((a >> shift) << bits) | (b >> shift)
    v = table[idx]
    total = 2 * shift
    ok = lz64(v) >= total
    r = select(ok, u64(v << total), M64)
    return select(v == 0, 0, r)


def vector_slut_flat(table, bits, half, a, b):
    shift = 25 - bits
    sa = -1 if a < 0 else 0
    mag_a = u32((a ^ sa) - sa)
    sb = -1 if b < 0 else 0
    mag_b = u32((b ^ sb) - sb)
    ia = i32((i32(mag_a >> shift) ^ sa) - sa)
    ib = i32((i32(mag_b >> shift) ^ sb) - sb)
    v = table[((ia + half) << bits) | (ib + half)]
    total = 2 * shift
    neg = v < 0
    mag_v = abs(v)
    ok = lz64(mag_v) > total
    sat = select(neg, -(1 << 63), (1 << 63) - 1)
    r = select(ok, i64(v << total), sat)
    return select(v == 0, 0, r)


# --- operand pools -----------------------------------------------------

EDGE_U32 = [
    0, 1, 2, 3, 7, 8, 63, 64, 255, 256, 1 << 15, (1 << 16) - 1,
    1 << 22, (1 << 23) - 1, 1 << 23, (1 << 23) + 1, (1 << 24) - 1,
    1 << 24, (1 << 31) - 1, 1 << 31, M32 - 1, M32,
]
EDGE_I32 = sorted(
    {i32(v) for v in EDGE_U32}
    | {-(1 << 31), -(1 << 31) + 1, -1, -2, -(1 << 23), (1 << 23) - 1, 1 << 23}
)
MANT = [1 << 23, (1 << 23) + 1, (1 << 24) - 1, 0xABCDEF | (1 << 23)]


def rand_u32(rng):
    return rng.getrandbits(32)


def rand_i32(rng):
    return i32(rng.getrandbits(32))


def rand_mant(rng):
    return (1 << 23) | rng.getrandbits(23)


FAILURES = []


def check(name, want, got, ctx):
    if want != got:
        FAILURES.append(f"{name}: want {want} got {got} ({ctx})")
        if len(FAILURES) < 20:
            print(f"FAIL {FAILURES[-1]}")


def sweep_pair(name, scalar_fn, vector_fn, edges, rand_fn, rng, n=20000):
    pool = list(edges)
    for a in pool:
        for b in pool:
            check(name, scalar_fn(a, b), vector_fn(a, b), f"{a},{b}")
    for _ in range(n):
        a, b = rand_fn(rng), rand_fn(rng)
        check(name, scalar_fn(a, b), vector_fn(a, b), f"{a},{b}")


def main():
    rng = random.Random(20260808)

    for k in (3, 4, 6, 8, 23, 24, 31, 32):
        sweep_pair(
            f"drum{k}",
            lambda a, b, k=k: scalar_drum(k, a, b),
            lambda a, b, k=k: vector_drum(k, a, b),
            EDGE_U32, rand_u32, rng, 4000,
        )
    for k in (1, 4, 8, 12, 16, 24, 31):
        sweep_pair(
            f"trunc{k}",
            lambda a, b, k=k: scalar_trunc(k, a, b),
            lambda a, b, k=k: vector_trunc(k, a, b),
            EDGE_U32, rand_u32, rng, 2000,
        )
    sweep_pair("mitchell", scalar_mitchell, vector_mitchell, EDGE_U32,
               rand_u32, rng, 20000)
    for k in (3, 4, 6, 8, 24, 32):
        sweep_pair(
            f"sdrum{k}",
            lambda a, b, k=k: scalar_sdrum(k, a, b),
            lambda a, b, k=k: vector_sdrum(k, a, b),
            EDGE_I32, rand_i32, rng, 4000,
        )
    for k in (0, 4, 8, 12, 24, 32):
        sweep_pair(
            f"booth{k}",
            lambda a, b, k=k: scalar_booth(k, a, b),
            lambda a, b, k=k: vector_booth(k, a, b),
            EDGE_I32, rand_i32, rng, 4000,
        )

    # Flat LUT kernels on the GEMM mantissa domain, including a table
    # with planted zero / huge cells so the saturation legs are hit.
    bits = 8
    size = 1 << bits
    table = [scalar_drum(6, a, b) for a in range(size) for b in range(size)]
    table[(130 << bits) | 131] = 0
    table[(200 << bits) | 201] = M64 >> 3  # forces saturation
    for _ in range(20000):
        a, b = rand_mant(rng), rand_mant(rng)
        check("lut8-flat", scalar_lut_flat(table, bits, a, b),
              vector_lut_flat(table, bits, a, b), f"{a},{b}")
    # Every index the mantissa domain can produce is in [2^(b-1), 2^b).
    assert all(
        (1 << (bits - 1)) <= (m >> (24 - bits)) < (1 << bits)
        for m in [1 << 23, (1 << 24) - 1]
    )

    half = size // 2
    stable = [scalar_booth(8, i32(r - half), i32(c - half))
              for r in range(size) for c in range(size)]
    stable[(5 << bits) | 7] = 0
    stable[(17 << bits) | 9] = -(1 << 62)  # negative saturation leg
    stable[(18 << bits) | 9] = (1 << 62)   # positive saturation leg
    for _ in range(20000):
        a = rand_mant(rng) * rng.choice((1, -1))
        b = rand_mant(rng) * rng.choice((1, -1))
        check("slut8-flat", scalar_slut_flat(stable, bits, half, a, b),
              vector_slut_flat(stable, bits, half, a, b), f"{a},{b}")

    # Vector renorm vs scalar renorm (esum spans under/overflow bands;
    # p == 0 lanes included — the select ordering under test).
    for _ in range(40000):
        sign = rng.getrandbits(1)
        esum = rng.randrange(2, 511)
        choice = rng.randrange(4)
        if choice == 0:
            p = 0
        elif choice == 1:
            p = rng.getrandbits(64)
        elif choice == 2:
            p = rand_mant(rng) * rand_mant(rng)
        else:
            p = rng.getrandbits(rng.randrange(1, 65))
        check("renorm", scalar_renorm(sign, esum, 0, p),
              vector_renorm(sign, esum, p), f"{sign},{esum},{p}")
    for p in (0, 1, M64, 1 << 63, (1 << 47) - 1, 1 << 46):
        for esum in (0, 1, 126, 173, 300, 427, 428, 510):
            for sign in (0, 1):
                check("renorm-edge", scalar_renorm(sign, esum, 0, p),
                      vector_renorm(sign, esum, p), f"{sign},{esum},{p}")

    # The chain argument: summing the full per-k term list (with +0.0
    # for flushed/dummy lanes) is bit-identical to summing the compact
    # list that skips them, because an f32 accumulator can never be
    # -0.0 mid-chain. Terms include -0.0 (underflowed renorm), ±inf and
    # NaN (non-finite fallbacks).
    special_bits = [
        0x00000000, 0x80000000,            # ±0
        0x7F800000, 0xFF800000,            # ±inf
        0x7FC00000,                        # NaN
        0x00000001,                        # subnormal
    ]
    for trial in range(20000):
        n = rng.randrange(1, 33)
        terms = []
        for _ in range(n):
            if rng.randrange(8) == 0:
                terms.append(f32_from_bits(rng.choice(special_bits)))
            else:
                b = (rng.getrandbits(1) << 31) | (rng.randrange(1, 255) << 23) \
                    | rng.getrandbits(23)
                terms.append(f32_from_bits(b))
        flush = [rng.randrange(4) == 0 for _ in range(n)]
        acc_full = f32_from_bits(0)
        acc_skip = f32_from_bits(0)
        for t, fl in zip(terms, flush):
            acc_full = f32_add(acc_full, 0.0 if fl else t)
            if not fl:
                acc_skip = f32_add(acc_skip, t)
        bf, bs = f32_to_bits(acc_full), f32_to_bits(acc_skip)
        # NaN payloads may differ representationally in Python; compare
        # NaN-as-class, everything else bitwise.
        if not (acc_full != acc_full and acc_skip != acc_skip):
            check("chain-skip", bs, bf, f"trial {trial}")

    if FAILURES:
        print(f"{len(FAILURES)} failures")
        return 1
    print("all SIMD recipes match their scalar transcriptions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
